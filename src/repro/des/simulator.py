"""Vidur-style discrete-event simulator — the baseline Revati replaces.

This is a deliberate, faithful instance of the approach the paper critiques
(§2.2–2.3): the serving system's control logic is *re-implemented* inside an
event loop.  It models continuous batching with chunked prefill (the ~150
lines Vidur needed for the original vLLM scheduler) and shares Revati's
runtime predictor, so any output divergence from the emulator is purely the
**semantic gap** of re-implementation — not a cost-model difference.

Multi-replica mode: ``num_replicas > 1`` runs N independent replica engines
inside one merged event loop, with request placement delegated to the same
pluggable :class:`~repro.cluster.router.Router` policies that route the
emulator's real engines.  Using identically-constructed policy objects
(routers are stateful — build a fresh one per run) pins routing behaviour
equal by construction, so emulator-vs-DES divergence at cluster scale is
attributable purely to engine-semantics re-implementation — extending the
paper's semantic-gap argument to N replicas.

Elastic mode: the simulator consumes the same
:class:`~repro.cluster.autoscaler.AutoscalerPolicy` objects as the emulated
cluster — policy ticks are events every ``interval_s``, scale-ups append a
fresh replica after the modeled ``provision_delay_s``, and scale-downs pick
their victim through the shared
:func:`~repro.cluster.autoscaler.drain_victim` rule (most expensive idle
tier first, index tie-break — literally the same function the emulator's
Autoscaler calls), so emulator-vs-DES parity extends to runs where replicas
join and leave mid-stream, on mixed pools included.

Heterogeneous mode: ``replica_tiers`` gives each replica a hardware tier;
``tier_predictors`` supplies the per-tier step-time predictors and
``tier_specs`` the shared :class:`~repro.cluster.tiers.TierSpec` arithmetic
(router throughput weights, $/replica-second, tier-selection inputs).  Build
the spec dict **once** (``repro.cluster.tiers.make_tier_specs``) and pass the
same mapping to ``build_cluster`` and here: tier-aware routing weights,
scale-up tier choices (``policy.select_tier`` at tick time), and per-tier
provisioning delays then agree between emulator and DES by construction,
extending the parity argument to mixed pools.

Closed-loop mode: ``run`` also accepts a
:class:`~repro.workload.session.SessionWorkload`; turn completions re-inject
the pre-sampled follow-up turns through the *same* ``follow_up`` rule the
emulator's completion callbacks use.

Intentionally (and realistically) missing, mirroring Table 1's "VD" column:
prefix caching (so ``prefix_affinity`` routing degrades to its sticky-map
fallback — a DES replica can never report a cache hit), hierarchical cache
tiers, preemption-by-recompute, per-framework batching quirks, and the
``pd_pool`` policy's KV migration (re-implementing it here would be exactly
the re-implementation burden the paper critiques, so it raises instead).
``benchmarks/table1_features`` quantifies the resulting error on workloads
that exercise those features.
"""

from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass
from typing import List, Optional, Tuple

from repro.core.predictor import BatchSpec, RuntimePredictor, SeqSpec


@dataclass
class DESConfig:
    max_num_seqs: int = 64
    max_batched_tokens: int = 512
    step_overhead_s: float = 20e-6     # modelled CPU overhead per step


@dataclass
class SimRequest:
    request_id: int
    prompt_len: int
    max_new_tokens: int
    arrival_time: float
    num_prefilled: int = 0
    num_generated: int = 0
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    failed: bool = False                           # crash, on_crash="fail"
    replica: int = -1                              # placement decision
    prompt_tokens: Optional[Tuple[int, ...]] = None  # routing key only
    session_id: Optional[int] = None               # closed-loop identity
    turn_index: int = 0
    tenant: Optional[str] = None                   # fleet ingress tag
    adapter: Optional[str] = None                  # LoRA adapter (routing key)

    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival_time

    def tpot(self) -> Optional[float]:
        if self.finish_time is None or self.first_token_time is None:
            return None
        n = self.num_generated - 1
        return (self.finish_time - self.first_token_time) / n if n > 0 else 0.0


class _ReplicaState:
    """One simulated engine replica: queues + in-flight step bookkeeping.

    Also the replica's :class:`~repro.cluster.router.ReplicaView`: routing
    probes answer from event-loop state.  ``prefix_match_len`` is always 0 —
    the DES models no radix cache (Table 1), which is itself part of the
    semantic gap the multi-replica comparison measures.
    """

    def __init__(self, index: int, added_at: float = 0.0,
                 tier: Optional[str] = None, predictor=None):
        self.index = index
        self.waiting: List[SimRequest] = []
        self.running: List[SimRequest] = []
        self.step_in_flight = False
        self.in_flight_batch: List[Tuple[SimRequest, int]] = []
        self.added_at = added_at
        self.drained_at: Optional[float] = None
        self.dead = False                # crashed/reclaim-killed (faults)
        self.tier = tier                 # hardware tier name (None = untiered)
        self.predictor = predictor       # tier-resolved step-time predictor

    # ------------------------------------------------------- ReplicaView --
    def outstanding_tokens(self) -> int:
        total = 0
        for s in self.waiting + self.running:
            total += max(s.prompt_len - s.num_prefilled, 0)
            total += max(s.max_new_tokens - s.num_generated, 0)
        return total

    def num_outstanding(self) -> int:
        return len(self.waiting) + len(self.running)

    def prefix_match_len(self, tokens) -> int:
        return 0

    def idle(self) -> bool:
        return not (self.waiting or self.running or self.step_in_flight)


class _DESView:
    """AutoscalerView over event-loop state (mirror of the emulator's)."""

    def __init__(self, sim: "DiscreteEventSimulator"):
        self._sim = sim
        self._now = 0.0

    def now(self) -> float:
        return self._now

    def active_count(self) -> int:
        return len(self._sim.active)

    def queue_depths(self) -> List[int]:
        return [self._sim.replicas[i].num_outstanding()
                for i in self._sim.active]

    def recent_ttfts(self, window_s: float) -> List[float]:
        horizon = self._now - window_s
        return [t for ft, t in self._sim._finish_log if ft >= horizon]


class DiscreteEventSimulator:
    """Event-driven re-implementation of a vLLM-like engine (1..N replicas,
    optionally elastic and closed-loop)."""

    ARRIVAL, STEP_DONE, TICK, PROVISION = 0, 1, 2, 3
    # fault events, mirroring repro.cluster.faults.FaultInjector one-to-one
    CRASH, STRAGGLE, STRAGGLE_END = 4, 5, 6
    RECLAIM, RECLAIM_KILL, RESPAWN = 7, 8, 9

    def __init__(
        self,
        predictor: RuntimePredictor,
        cfg: Optional[DESConfig] = None,
        *,
        num_replicas: int = 1,
        router=None,                 # repro.cluster.router.Router
        autoscaler_policy=None,      # repro.cluster.autoscaler.AutoscalerPolicy
        autoscaler_cfg=None,         # repro.cluster.autoscaler.AutoscalerConfig
        replica_tiers=None,          # per-replica tier names (heterogeneous)
        tier_predictors=None,        # tier name -> RuntimePredictor
        tier_specs=None,             # tier name -> repro.cluster.tiers.TierSpec
        faults=None,                 # iterable of repro.cluster.faults.FaultSpec
    ):
        self.predictor = predictor
        # per-instance default: a shared mutable default DESConfig would
        # alias config state across simulators
        self.cfg = cfg if cfg is not None else DESConfig()
        self.num_replicas = num_replicas
        self.replica_tiers = (list(replica_tiers) if replica_tiers is not None
                              else [None] * num_replicas)
        if len(self.replica_tiers) != num_replicas:
            raise ValueError(
                f"need {num_replicas} tier names, "
                f"got {len(self.replica_tiers)}")
        self.tier_predictors = dict(tier_predictors or {})
        self.tier_specs = dict(tier_specs or {})
        for t in set(self.replica_tiers):
            if t is not None and t not in self.tier_specs:
                raise ValueError(
                    f"replica tier {t!r} has no TierSpec; build one dict via "
                    "repro.cluster.tiers.make_tier_specs and share it with "
                    "build_cluster")
        if router is not None and getattr(router, "policy", None) == "pd_pool":
            raise ValueError(
                "the DES baseline does not model PD disaggregation "
                "(KV migration would need re-implementation — the exact "
                "burden the paper critiques); use the cluster emulator")
        if router is not None and router.num_replicas != num_replicas:
            raise ValueError(
                f"router sized for {router.num_replicas} replicas, "
                f"simulator has {num_replicas}")
        self.router = router
        self.autoscaler_policy = autoscaler_policy
        self.autoscaler_cfg = autoscaler_cfg
        self.faults = list(faults or [])
        self.replicas: List[_ReplicaState] = []
        self.active: List[int] = []
        # fault-injection audit, filled per run(); tuples identical to
        # FaultInjector.events (nominal times, primitives) for compare()
        self.fault_log: List[tuple] = []
        self.failed: List[SimRequest] = []
        self.requeued_total = 0
        self.recoveries: List[Tuple[float, float]] = []
        self._finish_log: List[Tuple[float, float]] = []   # (finish, ttft)
        # sink mode prunes the TTFT log to this sliding window of virtual
        # seconds; keep it comfortably wider than any autoscaler policy's
        # recent_ttfts() window
        self.finish_log_window_s: float = 300.0

    # ----------------------------------------------------------- plumbing --
    @staticmethod
    def _to_sim(r, request_id: int) -> SimRequest:
        toks = getattr(r, "prompt_tokens", None)
        plen = getattr(r, "prompt_len", None) or len(toks)
        return SimRequest(
            request_id=request_id, prompt_len=plen,
            max_new_tokens=r.max_new_tokens,
            arrival_time=r.arrival_time,
            prompt_tokens=tuple(toks) if toks is not None else None,
            session_id=getattr(r, "session_id", None),
            turn_index=getattr(r, "turn_index", 0),
            tenant=getattr(r, "tenant", None),
            adapter=getattr(r, "adapter", None))

    def _tier_predictor(self, tier: Optional[str]):
        if tier is not None and tier in self.tier_predictors:
            return self.tier_predictors[tier]
        return self.predictor

    def replica_seconds(self, t_end: float) -> float:
        """Capacity proxy matching :meth:`Cluster.replica_seconds`."""
        total = 0.0
        for rep in self.replicas:
            end = rep.drained_at if rep.drained_at is not None else t_end
            total += max(0.0, min(end, t_end) - rep.added_at)
        return total

    def replica_cost(self, t_end: float) -> float:
        """Dollar cost matching :meth:`Cluster.replica_cost` (untiered
        replicas cost $0)."""
        total = 0.0
        for rep in self.replicas:
            if rep.tier is None:
                continue
            end = rep.drained_at if rep.drained_at is not None else t_end
            on = max(0.0, min(end, t_end) - rep.added_at)
            total += on * self.tier_specs[rep.tier].cost_per_replica_s
        return total

    # ---------------------------------------------------------------- run --
    def run(self, requests, *, sink=None) -> List[SimRequest]:
        """``requests``: an iterable of request-like objects (repro Request
        or SimRequest: prompt_tokens/prompt_len, max_new_tokens,
        arrival_time) **or** a SessionWorkload (closed loop).

        Lists/tuples and eager SessionWorkloads are materialized up front
        (historical behaviour, byte-identical event order).  Any other
        iterable — a generator, :class:`~repro.workload.StreamingWorkload`,
        or a :class:`~repro.workload.StreamingSessionWorkload` (consumed via
        ``initial_stream``) — is pulled lazily with one-arrival look-ahead,
        so the event heap never holds the whole workload.  Lazy sources must
        yield non-decreasing ``arrival_time``.

        ``sink``: optional callable receiving each completed
        :class:`SimRequest` as it finishes.  When set, completed requests
        are **not** retained (``run`` returns an empty list) and the
        autoscaler's TTFT finish-log is pruned to a sliding window of
        ``finish_log_window_s`` virtual seconds — the flat-memory scale
        path.
        """
        from repro.cluster.router import RoundRobinRouter

        router = self.router or RoundRobinRouter(self.num_replicas)

        session_workload = None
        stream = None
        if hasattr(requests, "initial_stream"):   # streaming closed loop
            session_workload = requests
            stream = iter(requests.initial_stream())
            source = ()
            expected = requests.total_requests
        elif hasattr(requests, "initial_requests"):    # eager SessionWorkload
            session_workload = requests
            source = session_workload.initial_requests()
            expected = session_workload.total_requests
        elif isinstance(requests, (list, tuple)):
            source = list(requests)
            expected = len(source)
        else:                                     # lazy open-loop stream
            stream = iter(requests)
            source = ()
            expected = getattr(requests, "total_requests", None)
            if expected is None:
                expected = getattr(requests, "expected", None)
        if self.autoscaler_policy is not None and expected is None:
            raise ValueError(
                "elastic DES needs a declared request count to know when to "
                "stop ticking; pass a workload exposing .expected / "
                ".total_requests instead of a bare generator")

        req_counter = itertools.count()
        sims: List[SimRequest] = [self._to_sim(r, next(req_counter))
                                  for r in source]

        self.replicas = [
            _ReplicaState(i, tier=self.replica_tiers[i],
                          predictor=self._tier_predictor(self.replica_tiers[i]))
            for i in range(self.num_replicas)
        ]
        # mirror of Cluster.__init__'s tier wiring: routing policies see the
        # same per-replica throughput weights / $ rates on both sides
        for i, t in enumerate(self.replica_tiers):
            if t is not None:
                spec = self.tier_specs[t]
                router.set_tier(i, weight=spec.throughput_factor,
                                cost=spec.cost_per_replica_s)
        self.active = list(range(self.num_replicas))
        self._finish_log = []
        self.fault_log = []
        self.failed = []
        self.requeued_total = 0
        self.recoveries = []
        asc_cfg = self.autoscaler_cfg
        if self.autoscaler_policy is not None and asc_cfg is None:
            from repro.cluster.autoscaler import AutoscalerConfig
            asc_cfg = AutoscalerConfig()
        asc_tier_specs = []
        if asc_cfg is not None and getattr(asc_cfg, "tiers", ()):
            missing = [t for t in asc_cfg.tiers if t not in self.tier_specs]
            if missing:
                raise ValueError(
                    f"autoscaler tiers {missing} have no TierSpec; pass "
                    "tier_specs= (shared with the emulated cluster)")
            asc_tier_specs = [self.tier_specs[t] for t in asc_cfg.tiers]
        view = _DESView(self)
        if self.autoscaler_policy is not None:
            # Same anchoring call the emulator's Autoscaler makes at start:
            # the DES timeline originates at 0.0 by construction.
            self.autoscaler_policy.set_origin(0.0)

        counter = itertools.count()
        # event payload: SimRequest for ARRIVAL, replica index for STEP_DONE,
        # None for TICK / PROVISION
        events: List[Tuple[float, int, int, object]] = []
        for s in sims:
            heapq.heappush(events, (s.arrival_time, next(counter), self.ARRIVAL, s))
        if self.autoscaler_policy is not None:
            heapq.heappush(events, (asc_cfg.interval_s, next(counter),
                                    self.TICK, None))
        if self.faults:
            # the SAME static schedule expansion the emulator's FaultInjector
            # pops: one heap walk, so relative order of same-time faults is
            # pinned equal across backends
            from repro.cluster.faults import schedule_of
            _kind_of = {"crash": self.CRASH, "straggle": self.STRAGGLE,
                        "straggle_end": self.STRAGGLE_END,
                        "reclaim": self.RECLAIM}
            sched = schedule_of(self.faults)
            while sched:
                f_t, _, action, f_spec = heapq.heappop(sched)
                heapq.heappush(events, (f_t, next(counter),
                                        _kind_of[action], f_spec))

        def pull_source() -> Optional[SimRequest]:
            """Next source arrival from a lazy stream (None when drained)."""
            try:
                r = next(stream)
            except StopIteration:
                return None
            s = self._to_sim(r, next(req_counter))
            if sink is None:
                sims.append(s)
            return s

        pending = pull_source() if stream is not None else None

        now = 0.0
        completed = 0
        provisioning = 0

        def schedule_step(rep: _ReplicaState):
            if rep.step_in_flight:
                return
            batch: List[Tuple[SimRequest, int]] = []
            budget = self.cfg.max_batched_tokens
            # decodes first (mixed batching)
            for s in rep.running:
                if s.num_prefilled >= s.prompt_len:
                    batch.append((s, 1))
            # chunked prefill continuation + FCFS admission
            for s in rep.running:
                if budget <= 0:
                    break
                if s.num_prefilled < s.prompt_len:
                    chunk = min(budget, s.prompt_len - s.num_prefilled)
                    batch.append((s, chunk))
                    budget -= chunk
            while (budget > 0 and rep.waiting
                   and len(rep.running) < self.cfg.max_num_seqs):
                s = rep.waiting.pop(0)
                rep.running.append(s)
                chunk = min(budget, s.prompt_len)
                batch.append((s, chunk))
                budget -= chunk
            if not batch:
                return
            spec = BatchSpec.make([
                SeqSpec(n, s.num_prefilled + s.num_generated + n)
                for s, n in batch
            ])
            dur = rep.predictor.predict_step(spec).total + self.cfg.step_overhead_s
            rep.in_flight_batch = batch
            rep.step_in_flight = True
            heapq.heappush(
                events, (now + dur, next(counter), self.STEP_DONE, rep.index))

        def pick_drain_victim() -> Optional[int]:
            # the exact rule object the emulator's Autoscaler._pick_victim
            # calls: most expensive idle tier first, index tie-break
            from repro.cluster.autoscaler import drain_victim

            def cost_of(i: int) -> float:
                t = self.replicas[i].tier
                return 0.0 if t is None else self.tier_specs[t].cost_per_replica_s

            return drain_victim(self.active,
                                idle_of=lambda i: self.replicas[i].idle(),
                                cost_of=cost_of)

        def apply_autoscale(delta: int):
            nonlocal provisioning
            from repro.cluster.autoscaler import provision_delay
            committed = len(self.active) + provisioning
            if delta > 0:
                delta = min(delta, asc_cfg.max_replicas - committed)
                for _ in range(max(0, delta)):
                    provisioning += 1
                    # tier choice happens at tick time, mirroring
                    # Autoscaler._apply; the PROVISION event carries it
                    tier = None
                    if asc_tier_specs:
                        tier = self.autoscaler_policy.select_tier(
                            view, asc_tier_specs).name
                    heapq.heappush(
                        events, (now + provision_delay(asc_cfg, tier),
                                 next(counter), self.PROVISION, tier))
            elif delta < 0:
                allowed = max(0, committed - asc_cfg.min_replicas)
                for _ in range(min(-delta, allowed)):
                    victim = pick_drain_victim()
                    if victim is None:
                        break
                    self.active.remove(victim)
                    rep = self.replicas[victim]
                    if rep.idle():
                        rep.drained_at = now

        def crash_now(idx: int, spec, *, log_kind: str):
            """Kill replica ``idx`` with crash semantics — the DES mirror of
            ``ClusterBase.crash_replica`` + ``FaultInjector._apply_crash``,
            guard-for-guard: missing/drained/last-active replicas refuse,
            the log records nominal time, victims sort by
            ``(arrival_time, request_id)`` and re-route (or fail)."""
            t = now
            if idx >= len(self.replicas):
                self.fault_log.append((log_kind, t, idx, 0, 0, False))
                return
            rep = self.replicas[idx]
            if rep.dead or rep.drained_at is not None:
                self.fault_log.append((log_kind, t, idx, 0, 0, False))
                return
            if idx in self.active:
                if len(self.active) <= 1:
                    self.fault_log.append((log_kind, t, idx, 0, 0, False))
                    return
                self.active.remove(idx)
            rep.dead = True
            rep.drained_at = now          # cost window closes at the crash
            # in_flight_batch entries are running-list members; the step's
            # STEP_DONE event stays on the heap but is skipped (rep.dead) —
            # the step never completes, its tokens are lost with the KV
            victims = list(rep.waiting) + list(rep.running)
            rep.waiting.clear()
            rep.running.clear()
            rep.in_flight_batch = []
            rep.step_in_flight = False
            victims.sort(key=lambda s: (s.arrival_time, s.request_id))
            requeued = failed_n = 0
            if spec.on_crash == "requeue":
                for s in victims:
                    s.num_prefilled = 0
                    s.num_generated = 0
                    s.first_token_time = None
                    s.finish_time = None
                    tgt = router.route(s, self.replicas, active=self.active)
                    s.replica = tgt
                    self.replicas[tgt].waiting.append(s)
                for tgt in sorted({s.replica for s in victims}):
                    schedule_step(self.replicas[tgt])
                requeued = len(victims)
                self.requeued_total += requeued
            else:
                for s in victims:
                    s.failed = True
                self.failed.extend(victims)
                failed_n = len(victims)
            self.fault_log.append((log_kind, t, idx, requeued, failed_n, True))
            if spec.recover:
                tier = (spec.respawn_tier if spec.respawn_tier is not None
                        else rep.tier)
                heapq.heappush(events, (t + spec.respawn_delay_s,
                                        next(counter), self.RESPAWN,
                                        (tier, t)))

        while events or pending is not None:
            # One-ahead merge of the lazy source with the event heap.  Ties
            # go to the source arrival — the exact order the eager path
            # produces, where every source arrival's heap counter precedes
            # any event scheduled during the run.
            if pending is not None and (
                    not events or pending.arrival_time <= events[0][0]):
                now, kind, payload = pending.arrival_time, self.ARRIVAL, pending
                pending = pull_source()
                if pending is not None and pending.arrival_time < now:
                    raise ValueError(
                        "lazy request streams must yield non-decreasing "
                        f"arrival times (got {pending.arrival_time} after "
                        f"{now})")
            else:
                now, _, kind, payload = heapq.heappop(events)
            if kind == self.ARRIVAL:
                idx = router.route(payload, self.replicas, active=self.active)
                payload.replica = idx
                rep = self.replicas[idx]
                rep.waiting.append(payload)
                schedule_step(rep)
            elif kind == self.STEP_DONE:
                rep = self.replicas[payload]
                if rep.dead:
                    continue      # step of a crashed replica: tokens lost
                rep.step_in_flight = False
                for s, n in rep.in_flight_batch:
                    if s.num_prefilled < s.prompt_len:
                        s.num_prefilled += n
                        if s.num_prefilled >= s.prompt_len:
                            s.num_generated += 1
                            if s.first_token_time is None:
                                s.first_token_time = now
                    else:
                        s.num_generated += 1
                    if (s.num_prefilled >= s.prompt_len
                            and s.num_generated >= s.max_new_tokens
                            and s.finish_time is None):
                        s.finish_time = now
                        rep.running.remove(s)
                        completed += 1
                        # the finish log only feeds autoscaler policies
                        # (AutoscalerView.recent_ttfts); in sink mode it is
                        # pruned to a sliding window — and skipped outright
                        # when nothing will ever read it — to keep memory
                        # flat over million-request streams
                        log_ttfts = (sink is None
                                     or self.autoscaler_policy is not None)
                        if s.ttft() is not None and log_ttfts:
                            self._finish_log.append((now, s.ttft()))
                            if sink is not None:
                                horizon = now - self.finish_log_window_s
                                log = self._finish_log
                                cut = 0
                                while cut < len(log) and log[cut][0] < horizon:
                                    cut += 1
                                if cut:
                                    del log[:cut]
                        if session_workload is not None:
                            fu = session_workload.follow_up(s)
                            if fu is not None:
                                fu_sim = self._to_sim(fu, next(req_counter))
                                if sink is None:
                                    sims.append(fu_sim)
                                heapq.heappush(
                                    events, (fu_sim.arrival_time,
                                             next(counter), self.ARRIVAL,
                                             fu_sim))
                        if sink is not None:
                            sink(s)
                rep.in_flight_batch = []
                schedule_step(rep)
                if (rep.index not in self.active and rep.idle()
                        and rep.drained_at is None):
                    rep.drained_at = now         # drain complete
            elif kind == self.TICK:
                view._now = now
                apply_autoscale(self.autoscaler_policy.decide(view))
                if completed + len(self.failed) < expected:
                    heapq.heappush(events, (now + asc_cfg.interval_s,
                                            next(counter), self.TICK, None))
            elif kind == self.PROVISION:
                provisioning -= 1
                idx = len(self.replicas)
                # payload is the tier chosen at tick time; None clones the
                # last replica's tier (Cluster.add_replica's default)
                tier = payload if payload is not None \
                    else self.replicas[-1].tier
                self.replicas.append(_ReplicaState(
                    idx, added_at=now, tier=tier,
                    predictor=self._tier_predictor(tier)))
                self.active.append(idx)
                if tier is not None:
                    spec = self.tier_specs[tier]
                    router.grow(idx + 1, weight=spec.throughput_factor,
                                cost=spec.cost_per_replica_s)
                else:
                    router.grow(idx + 1)
            elif kind == self.CRASH:
                crash_now(payload.replica, payload, log_kind="crash")
            elif kind == self.STRAGGLE:
                from repro.cluster.faults import SlowdownPredictor
                if payload.replica < len(self.replicas):
                    rep = self.replicas[payload.replica]
                    rep.predictor = SlowdownPredictor(
                        rep.predictor, payload.slowdown)
                self.fault_log.append(("straggle", now, payload.replica,
                                       payload.slowdown))
            elif kind == self.STRAGGLE_END:
                from repro.cluster.faults import SlowdownPredictor
                if payload.replica < len(self.replicas):
                    rep = self.replicas[payload.replica]
                    rep.predictor = SlowdownPredictor.unwrap(rep.predictor)
                self.fault_log.append(("straggle_end", now, payload.replica))
            elif kind == self.RECLAIM:
                # drain notice: victims leave routing now, keep working;
                # whoever is not fully drained at now+notice_s is killed
                victims = [i for i in list(self.active)
                           if self.replicas[i].tier == payload.tier]
                if victims and len(victims) >= len(self.active):
                    victims = victims[1:]   # never reclaim the whole pool
                self.fault_log.append(("reclaim", now, payload.tier,
                                       tuple(victims)))
                for idx in victims:
                    self.active.remove(idx)
                    rep = self.replicas[idx]
                    if rep.idle():
                        rep.drained_at = now
                if victims:
                    heapq.heappush(events, (now + payload.notice_s,
                                            next(counter), self.RECLAIM_KILL,
                                            (payload, victims)))
            elif kind == self.RECLAIM_KILL:
                f_spec, victims = payload
                for idx in victims:
                    crash_now(idx, f_spec, log_kind="reclaim_kill")
            else:  # RESPAWN
                tier, fault_t = payload
                idx = len(self.replicas)
                self.replicas.append(_ReplicaState(
                    idx, added_at=now, tier=tier,
                    predictor=self._tier_predictor(tier)))
                self.active.append(idx)
                if tier is not None:
                    spec = self.tier_specs[tier]
                    router.grow(idx + 1, weight=spec.throughput_factor,
                                cost=spec.cost_per_replica_s)
                else:
                    router.grow(idx + 1)
                self.fault_log.append(("respawn", now, tier, idx))
                self.recoveries.append((fault_t, now))

        return sims
