"""Vidur-style discrete-event simulator — the baseline Revati replaces.

This is a deliberate, faithful instance of the approach the paper critiques
(§2.2–2.3): the serving system's control logic is *re-implemented* inside an
event loop.  It models continuous batching with chunked prefill (the ~150
lines Vidur needed for the original vLLM scheduler) and shares Revati's
runtime predictor, so any output divergence from the emulator is purely the
**semantic gap** of re-implementation — not a cost-model difference.

Intentionally (and realistically) missing, mirroring Table 1's "VD" column:
prefix caching, hierarchical cache tiers, preemption-by-recompute, PD
disaggregation, per-framework batching quirks.  ``benchmarks/table1_features``
quantifies the resulting error on workloads that exercise those features.
"""

from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.predictor import BatchSpec, RuntimePredictor, SeqSpec


@dataclass
class DESConfig:
    max_num_seqs: int = 64
    max_batched_tokens: int = 512
    step_overhead_s: float = 20e-6     # modelled CPU overhead per step


@dataclass
class SimRequest:
    request_id: int
    prompt_len: int
    max_new_tokens: int
    arrival_time: float
    num_prefilled: int = 0
    num_generated: int = 0
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None

    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival_time

    def tpot(self) -> Optional[float]:
        if self.finish_time is None or self.first_token_time is None:
            return None
        n = self.num_generated - 1
        return (self.finish_time - self.first_token_time) / n if n > 0 else 0.0


class DiscreteEventSimulator:
    """Event-driven re-implementation of a vLLM-like engine."""

    ARRIVAL, STEP_DONE = 0, 1

    def __init__(self, predictor: RuntimePredictor, cfg: DESConfig = DESConfig()):
        self.predictor = predictor
        self.cfg = cfg

    def run(self, requests) -> List[SimRequest]:
        """``requests``: iterable of objects with prompt_tokens/prompt_len,
        max_new_tokens, arrival_time (repro Request or SimRequest)."""
        sims: List[SimRequest] = []
        for i, r in enumerate(requests):
            plen = getattr(r, "prompt_len", None) or len(r.prompt_tokens)
            sims.append(SimRequest(
                request_id=i, prompt_len=plen,
                max_new_tokens=r.max_new_tokens,
                arrival_time=r.arrival_time))

        counter = itertools.count()
        events: List[Tuple[float, int, int, Optional[SimRequest]]] = []
        for s in sims:
            heapq.heappush(events, (s.arrival_time, next(counter), self.ARRIVAL, s))

        waiting: List[SimRequest] = []
        running: List[SimRequest] = []
        step_in_flight = False
        now = 0.0
        in_flight_batch: List[Tuple[SimRequest, int]] = []

        def schedule_step():
            nonlocal step_in_flight, in_flight_batch
            if step_in_flight:
                return
            batch: List[Tuple[SimRequest, int]] = []
            budget = self.cfg.max_batched_tokens
            # decodes first (mixed batching)
            for s in running:
                if s.num_prefilled >= s.prompt_len:
                    batch.append((s, 1))
            # chunked prefill continuation + FCFS admission
            for s in running:
                if budget <= 0:
                    break
                if s.num_prefilled < s.prompt_len:
                    chunk = min(budget, s.prompt_len - s.num_prefilled)
                    batch.append((s, chunk))
                    budget -= chunk
            while budget > 0 and waiting and len(running) < self.cfg.max_num_seqs:
                s = waiting.pop(0)
                running.append(s)
                chunk = min(budget, s.prompt_len)
                batch.append((s, chunk))
                budget -= chunk
            if not batch:
                return
            spec = BatchSpec.make([
                SeqSpec(n, s.num_prefilled + s.num_generated + n)
                for s, n in batch
            ])
            dur = self.predictor.predict_step(spec).total + self.cfg.step_overhead_s
            in_flight_batch = batch
            step_in_flight = True
            heapq.heappush(events, (now + dur, next(counter), self.STEP_DONE, None))

        while events:
            now, _, kind, payload = heapq.heappop(events)
            if kind == self.ARRIVAL:
                waiting.append(payload)
                schedule_step()
            else:  # STEP_DONE
                step_in_flight = False
                for s, n in in_flight_batch:
                    if s.num_prefilled < s.prompt_len:
                        s.num_prefilled += n
                        if s.num_prefilled >= s.prompt_len:
                            s.num_generated += 1
                            if s.first_token_time is None:
                                s.first_token_time = now
                    else:
                        s.num_generated += 1
                    if (s.num_prefilled >= s.prompt_len
                            and s.num_generated >= s.max_new_tokens
                            and s.finish_time is None):
                        s.finish_time = now
                        running.remove(s)
                in_flight_batch = []
                schedule_step()

        return sims
