"""Training driver: real JAX training of any registry architecture.

On this CPU container it trains REDUCED configs end-to-end (the same
``train_step`` the dry-run lowers for the production mesh); on TPU the same
entry point scales out via ``--mesh``.  Demonstrates the full substrate:
synthetic LM data pipeline, AdamW + cosine schedule + microbatched gradient
accumulation + remat, and atomic checkpoint/restart (kill it mid-run and
relaunch with the same --ckpt-dir: it resumes from the newest step).

    PYTHONPATH=src python -m repro.launch.train --arch qwen2_5_3b \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def data_stream(vocab: int, batch: int, seq: int, seed: int, start_step: int):
    """Deterministic synthetic LM batches (restart-safe: keyed by step)."""
    step = start_step
    while True:
        rng = np.random.default_rng((seed, step))
        toks = rng.integers(1, vocab, size=(batch, seq + 1), dtype=np.int64)
        yield {
            "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32),
        }
        step += 1


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_5_3b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full published config (TPU-scale)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    from repro.configs import get_config, get_reduced_config
    from repro.models.checkpoint import (latest_step, restore_checkpoint,
                                         save_checkpoint)
    from repro.models.optim import (OptimizerConfig, init_adamw,
                                    make_train_step)
    from repro.models.transformer import build_model

    cfg = (get_config if args.full_config else get_reduced_config)(args.arch)
    if cfg.frontend is not None:
        print(f"note: {args.arch} frontend is stubbed; training "
              f"text-only on the backbone")
        cfg = cfg.replace(frontend=None, frontend_tokens=0)
    model = build_model(cfg)
    print(f"arch={cfg.arch_id}  params={cfg.param_count():,}")

    params = model.init(jax.random.key(0), jnp.float32)
    opt = init_adamw(params)
    opt_cfg = OptimizerConfig(lr=args.lr, warmup_steps=20,
                              total_steps=args.steps)
    step_fn = jax.jit(make_train_step(model, opt_cfg,
                                      microbatches=args.microbatches))

    start = 0
    if args.ckpt_dir:
        s = latest_step(args.ckpt_dir)
        if s is not None:
            params, opt, meta = restore_checkpoint(
                args.ckpt_dir, s, params, opt)
            start = int(meta["step"])
            print(f"restored checkpoint @ step {start}")

    stream = data_stream(cfg.vocab_size, args.batch, args.seq, seed=1234,
                         start_step=start)
    t0 = time.time()
    tokens_done = 0
    for step in range(start, args.steps):
        batch = next(stream)
        params, opt, metrics = step_fn(params, opt, batch)
        tokens_done += args.batch * args.seq
        if (step + 1) % args.log_every == 0:
            loss = float(metrics["loss"])
            gn = float(metrics["grad_norm"])
            tps = tokens_done / (time.time() - t0)
            print(f"step {step + 1:5d}  loss {loss:8.4f}  "
                  f"grad_norm {gn:8.3f}  lr {float(metrics['lr']):.2e}  "
                  f"{tps:,.0f} tok/s")
            assert np.isfinite(loss), "training diverged"
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            path = save_checkpoint(args.ckpt_dir, step + 1, params, opt)
            print(f"checkpoint -> {path}")

    print(f"done: {args.steps - start} steps in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
