"""Trip-count-aware cost analysis over compiled HLO text.

``compiled.cost_analysis()`` counts every computation **once** — a
``lax.scan`` (layers, microbatches, loss chunks) lowers to a ``while`` whose
body cost it therefore under-reports by the trip count (verified empirically:
a 10-iteration scanned matmul reports exactly 1 matmul of FLOPs).  All our
training/prefill programs are scan-heavy, so the dry-run cannot trust it.

This module re-derives the roofline numerators from ``compiled.as_text()``:

* walks the call graph from ENTRY, weighting each computation by the product
  of enclosing ``while`` trip counts (XLA annotates
  ``backend_config={"known_trip_count":{"n":...}}`` after loop analysis);
* **flops** — exact for ``dot`` (2 · |out| · |contraction|, shapes resolved
  through a per-computation symbol table), 1/elem for elementwise ops,
  |in| for reductions; dots dominate every model here so elementwise terms
  are noise-level corrections;
* **bytes** — per materializing op: operands + outputs (the same boundary
  rule XLA uses for fusions; bitcast/tuple plumbing is free);
* **collective bytes** — per collective op: result bytes × multiplier,
  split by kind (all-gather / all-reduce / reduce-scatter / all-to-all /
  collective-permute), for the ICI roofline term.

Everything is *per device*: post-SPMD modules are per-device programs.
"""

from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze_hlo", "HLOCost"]

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+(?:e[0-9]+[a-z0-9]*)?|pred)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|body)=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count\\?":{\\?"n\\?":\\?"(\d+)')
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

# ops that neither move data nor compute
_FREE = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
         "after-all", "opt-barrier", "partition-id", "replica-id", "iota",
         "domain"}

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "negate", "abs", "exponential",
    "exponential-minus-one", "log", "log-plus-one", "rsqrt", "sqrt", "tanh",
    "logistic", "sine", "cosine", "maximum", "minimum", "compare", "select",
    "and", "or", "xor", "not", "power", "remainder", "clamp", "convert",
    "floor", "ceil", "round-nearest-afz", "round-nearest-even", "sign",
    "is-finite", "atan2", "cbrt", "erf", "expm1", "log1p", "shift-left",
    "shift-right-logical", "shift-right-arithmetic", "stochastic-convert",
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


@dataclass
class _Op:
    name: str
    kind: str
    out_shapes: List[Tuple[str, Tuple[int, ...]]]
    operands: List[str]
    rest: str                  # attrs text (contracting dims, calls, config)


@dataclass
class _Computation:
    name: str
    ops: List[_Op] = field(default_factory=list)
    symtab: Dict[str, List[Tuple[str, Tuple[int, ...]]]] = field(
        default_factory=dict)
    root: Optional[_Op] = None


@dataclass
class HLOCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_bytes_by_op: Dict[str, float] = field(default_factory=dict)
    collective_ops: int = 0
    while_loops: int = 0
    unknown_trip_loops: int = 0

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "bytes_accessed": self.bytes,
            "collective_bytes": self.collective_bytes,
            "collective_bytes_by_op": dict(self.collective_bytes_by_op),
            "collective_ops": self.collective_ops,
            "while_loops": self.while_loops,
            "unknown_trip_loops": self.unknown_trip_loops,
        }


def _nbytes(shapes: List[Tuple[str, Tuple[int, ...]]]) -> float:
    total = 0.0
    for dtype, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dtype, 4)
    return total


def _nelems(shapes: List[Tuple[str, Tuple[int, ...]]]) -> float:
    total = 0.0
    for _, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


def _parse_shapes(type_text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(type_text):
        if dtype not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",")) if dims else ()
        out.append((dtype, shape))
    return out


def _parse_module(text: str) -> Tuple[Dict[str, _Computation], Optional[str]]:
    comps: Dict[str, _Computation] = {}
    entry: Optional[str] = None
    cur: Optional[_Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_RE.match(line)
            if m and line.endswith("{"):
                cur = _Computation(m.group(2))
                comps[cur.name] = cur
                if m.group(1):
                    entry = cur.name
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        is_root, name, type_text, kind, rest = m.groups()
        shapes = _parse_shapes(type_text)
        # operands: everything inside op( ... ) up to the matching close —
        # approximate by taking %refs before any "calls="/metadata attrs;
        # shape resolution only needs the first operands, refs are unique.
        operands = _OPERAND_RE.findall(rest.split("metadata=")[0])
        op = _Op(name, kind, shapes, operands, rest)
        cur.ops.append(op)
        cur.symtab[name] = shapes
        if is_root:
            cur.root = op
    return comps, entry


def _dot_flops(op: _Op, comp: _Computation) -> float:
    out_elems = _nelems(op.out_shapes)
    m = _CONTRACT_RE.search(op.rest)
    if not m or not op.operands:
        return 2.0 * out_elems
    lhs = comp.symtab.get(op.operands[0])
    if not lhs:
        return 2.0 * out_elems
    _, lhs_dims = lhs[0]
    contract = 1
    for idx in (int(i) for i in m.group(1).split(",") if i):
        if idx < len(lhs_dims):
            contract *= lhs_dims[idx]
    return 2.0 * out_elems * contract


def _operand_bytes(op: _Op, comp: _Computation) -> float:
    total = 0.0
    for ref in op.operands:
        shapes = comp.symtab.get(ref)
        if shapes:
            total += _nbytes(shapes)
    return total


# Slicing ops touch only the slice, not the buffer they index into — the
# same special case XLA's cost analysis applies.  Without it, a layer-scan
# body that dynamic-slices one layer's weights from the stacked (L, ...)
# array would be charged L× the real traffic on every iteration, and every
# KV-cache dynamic-update-slice would be charged the whole cache.
_SLICING = {"dynamic-slice", "dynamic-update-slice", "gather", "scatter"}


_PARAM_IDX_RE = re.compile(r"^(\d+)\)")


def _fusion_bytes(op: _Op, comp: _Computation, callee: _Computation) -> float:
    """Traffic of one fusion call: per-parameter slicing analysis.

    A parameter consumed *only* as the source of dynamic-slice/gather ops
    inside the fusion contributes the slice sizes, not the full buffer (the
    layer-scan weight access pattern).  The target of a root
    dynamic-update-slice is aliased in place and contributes only the
    update-region write (the KV-cache append pattern).  Everything else is
    streamed whole — XLA's fusion-boundary model.
    """
    # map parameter index -> param op name
    idx_to_name: Dict[int, str] = {}
    for o in callee.ops:
        if o.kind == "parameter":
            m = _PARAM_IDX_RE.match(o.rest)
            if m:
                idx_to_name[int(m.group(1))] = o.name
    # usage: param name -> list of (op kind, charged bytes if sliced)
    sliced_reads: Dict[str, float] = {}
    full_use: Dict[str, bool] = {}
    for o in callee.ops:
        if o.kind in _FREE and o.kind != "bitcast":
            continue
        for j, ref in enumerate(o.operands):
            if ref not in idx_to_name.values():
                continue
            if o.kind in ("dynamic-slice", "gather") and j == 0:
                sliced_reads[ref] = (sliced_reads.get(ref, 0.0)
                                     + _nbytes(o.out_shapes))
            elif o.kind == "bitcast":
                # bitcast aliases; treat as transparent full use only if the
                # bitcast itself is then used outside slicing — conservative:
                full_use[ref] = True
            else:
                full_use[ref] = True

    root = callee.root
    dus_target: Optional[str] = None
    out_b = _nbytes(op.out_shapes)
    # in-place-update roots: DUS (update = operand 1) and scatter
    # (updates = operand 2) write only the update region of an aliased target
    _upd_idx = {"dynamic-update-slice": 1, "scatter": 2}
    if root is not None and root.kind in _upd_idx:
        if root.operands:
            dus_target = root.operands[0]
        i = _upd_idx[root.kind]
        upd = (callee.symtab.get(root.operands[i])
               if len(root.operands) > i else None)
        out_b = _nbytes(upd) if upd else out_b     # write region only

    total = out_b
    for i, ref in enumerate(op.operands):
        shapes = comp.symtab.get(ref)
        if not shapes:
            continue
        pname = idx_to_name.get(i)
        if pname is not None and pname == dus_target:
            # aliased in-place target: whole-buffer read is free, but any
            # dynamic-slice reads out of it are real traffic
            total += sliced_reads.get(pname, 0.0)
            continue
        if (pname is not None and pname in sliced_reads
                and not full_use.get(pname)):
            total += sliced_reads[pname]            # slice-sized reads
        else:
            total += _nbytes(shapes)
    return total


def _materialized_bytes(op: _Op, comp: _Computation,
                        comps: Dict[str, _Computation]) -> float:
    """HBM traffic for one materializing op (op itself or a fusion)."""
    kind = op.kind
    if kind == "fusion":
        m = _CALLS_RE.search(op.rest)
        cc = comps.get(m.group(1)) if m else None
        if cc is not None:
            return _fusion_bytes(op, comp, cc)

    out_b = _nbytes(op.out_shapes)
    if kind in ("dynamic-slice", "gather"):
        # read slice + write output (+ small operands we ignore)
        return 2.0 * out_b
    if kind in ("dynamic-update-slice", "scatter"):
        # read update + write update region; the aliased target is untouched
        idx = 1 if kind == "dynamic-update-slice" else 2
        upd = (comp.symtab.get(op.operands[idx])
               if len(op.operands) > idx else None)
        upd_b = _nbytes(upd) if upd else out_b
        return 2.0 * upd_b
    return _operand_bytes(op, comp) + out_b


def analyze_hlo(text: str) -> HLOCost:
    comps, entry = _parse_module(text)
    cost = HLOCost()
    cost.collective_bytes_by_op = {k: 0.0 for k in _COLLECTIVES}
    if entry is None:
        return cost

    visiting: set = set()

    def walk(comp_name: str, mult: float, *, flops_only: bool) -> None:
        comp = comps.get(comp_name)
        if comp is None or comp_name in visiting:
            return
        visiting.add(comp_name)
        try:
            for op in comp.ops:
                kind = op.kind
                if kind == "while":
                    tm = _TRIP_RE.search(op.rest)
                    trips = int(tm.group(1)) if tm else 1
                    cost.while_loops += 1
                    if not tm:
                        cost.unknown_trip_loops += 1
                    body = _CALLS_RE.search(op.rest)
                    if body:
                        walk(body.group(1), mult * trips,
                             flops_only=flops_only)
                    cond = _COND_RE.search(op.rest)
                    if cond:
                        walk(cond.group(1), mult * (trips + 1),
                             flops_only=flops_only)
                    continue
                if kind in ("fusion", "call", "conditional", "async-start"):
                    # memory: the fusion boundary is the traffic unit
                    if not flops_only and kind == "fusion":
                        cost.bytes += mult * _materialized_bytes(
                            op, comp, comps)
                    callee = _CALLS_RE.search(op.rest)
                    if callee:
                        walk(callee.group(1), mult, flops_only=True)
                    continue
                if kind in _FREE:
                    continue

                # ---- flops ----
                if kind == "dot":
                    cost.flops += mult * _dot_flops(op, comp)
                elif kind in _ELEMENTWISE:
                    cost.flops += mult * _nelems(op.out_shapes)
                elif kind in ("reduce", "reduce-window"):
                    cost.flops += mult * _operand_bytes(op, comp) / 4.0

                # ---- bytes ----
                if not flops_only:
                    cost.bytes += mult * _materialized_bytes(op, comp, comps)

                # ---- collectives ----
                base = kind[:-len("-start")] if kind.endswith("-start") else kind
                if base in _COLLECTIVES and not flops_only:
                    nb = _nbytes(op.out_shapes)
                    cost.collective_bytes += mult * nb
                    cost.collective_bytes_by_op[base] = (
                        cost.collective_bytes_by_op.get(base, 0.0) + mult * nb)
                    cost.collective_ops += int(mult)
        finally:
            visiting.discard(comp_name)

    walk(entry, 1.0, flops_only=False)
    return cost
