"""Abstract input builders for the dry-run: every model entry point as
ShapeDtypeStruct trees + matching shardings (no device allocation, the
shannon/kernels pattern).

One cell = (architecture, shape, mesh).  ``build_cell`` returns everything
``dryrun.py`` needs to lower: the callable, the SDS args, in/out shardings,
and bookkeeping for the roofline report (model FLOPs, batch geometry).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, ShapeSpec, get_config
from repro.models.config import ModelConfig
from repro.models.optim import (AdamWState, OptimizerConfig, abstract_adamw,
                                make_train_step)
from repro.models.transformer import EncDecLM, build_model

# microbatch counts keyed by arch family size (activation-memory control;
# derived from the napkin math in EXPERIMENTS.md §Dry-run)
TRAIN_MICROBATCHES: Dict[str, int] = {
    "qwen2_5_3b": 8,
    "granite_3_8b": 8,
    "granite_8b": 8,
    "olmo_1b": 4,
    "llava_next_mistral_7b": 8,
    "dbrx_132b": 16,
    "mixtral_8x7b": 8,
    "recurrentgemma_2b": 8,
    "whisper_base": 4,
    "mamba2_370m": 4,
    "llama3_8b": 8,
    "llama3_70b": 16,
    "qwen3_30b_a3b": 8,
}


@dataclass
class Cell:
    arch: str
    shape: ShapeSpec
    fn: Callable                      # what to lower
    args: Tuple[Any, ...]             # SDS pytrees
    in_shardings: Tuple[Any, ...]
    donate_argnums: Tuple[int, ...]
    model_cfg: ModelConfig
    entry: str                        # train_step | prefill | serve_step
    tokens_per_step: int              # new tokens processed per lowered call
    opts: Tuple[str, ...] = ()        # §Perf hillclimb knobs applied


def _frontend_sds(cfg: ModelConfig, batch: int) -> Optional[jax.ShapeDtypeStruct]:
    if cfg.frontend is None:
        return None
    return jax.ShapeDtypeStruct((batch, cfg.frontend_tokens, cfg.d_model),
                                jnp.bfloat16)


def input_specs(arch: str, shape_name: str,
                mesh: Optional[Mesh] = None) -> Tuple[Any, ...]:
    """ShapeDtypeStruct stand-ins for every input of the (arch × shape)
    entry point — weak-type-correct, shardable, no device allocation.

    ``train_4k`` → (params, opt_state, {tokens, labels[, frontend_embeds]});
    ``prefill_*`` → (params, inputs, cache);
    ``decode_*``/``long_*`` → (params, cache, tokens (B,1)) for one
    ``serve_step`` against a KV cache of seq_len.  ``[audio]``/``[vlm]``
    entries carry precomputed frame/patch embeddings (the frontend stub).
    """
    if mesh is None:
        import numpy as np
        devs = np.asarray(jax.devices()[:1]).reshape(1, 1)
        mesh = Mesh(devs, ("data", "model"))
    return build_cell(arch, shape_name, mesh).args


def build_cell(arch: str, shape_name: str, mesh: Mesh,
               opts: Tuple[str, ...] = ()) -> Cell:
    """``opts`` are the §Perf hillclimb knobs (EXPERIMENTS.md):

    * ``kv_seq_shard``   — decode cells: shard the KV *sequence* dim over
      "model" instead of falling back to head_dim (whose contraction forces
      per-layer score all-reduces ∝ context length).
    * ``moe_a2a``        — MoE blocks run as an explicit shard_map
      dispatch/combine all-to-all over "model" (MaxText-style EP) instead
      of GSPMD auto-sharding of the sort+ragged_dot form.
    * ``scores_bf16``    — materialized attention scores in bf16 (the
      dense-attention lowering's HBM traffic halves; the TPU execution
      path is the Pallas flash kernel anyway, see DESIGN.md §8).
    """
    from .mesh import batch_shardings, cache_shardings, param_shardings

    opts = tuple(opts)
    cfg = get_config(arch)
    if "scores_bf16" in opts:
        cfg = cfg.replace(attn_scores_dtype="bfloat16")
    if "moe_a2a" in opts and cfg.moe is not None:
        cfg = cfg.replace(moe_impl="a2a")
    if "kv_defer_append" in opts:
        cfg = cfg.replace(kv_append="defer")
    shape = SHAPES[shape_name]
    model = build_model(cfg)
    params_sds = model.abstract_params(jnp.bfloat16)
    B, S = shape.global_batch, shape.seq_len

    if shape.kind == "train":
        p_sh = param_shardings(mesh, params_sds, "train")
        opt_sds = abstract_adamw(params_sds)
        o_sh = AdamWState(step=NamedSharding(mesh, P()),
                          mu=param_shardings(mesh, params_sds, "train"),
                          nu=param_shardings(mesh, params_sds, "train"))
        text_len = S - (cfg.frontend_tokens if cfg.frontend else 0)
        batch_sds: Dict[str, Any] = {
            "tokens": jax.ShapeDtypeStruct((B, text_len), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, text_len), jnp.int32),
        }
        fe = _frontend_sds(cfg, B)
        if fe is not None:
            batch_sds["frontend_embeds"] = fe
        b_sh = batch_shardings(mesh, batch_sds, batch=B)
        mb = TRAIN_MICROBATCHES.get(arch, 8)
        step = make_train_step(model, OptimizerConfig(), microbatches=mb,
                               remat=True)
        return Cell(
            arch=arch, shape=shape, fn=step,
            args=(params_sds, opt_sds, batch_sds),
            in_shardings=(p_sh, o_sh, b_sh),
            donate_argnums=(0, 1),
            model_cfg=cfg, entry="train_step",
            tokens_per_step=B * S, opts=opts,
        )

    # serving entries share params in "serve" mode
    p_sh = param_shardings(mesh, params_sds, "serve")

    if shape.kind == "prefill":
        cache_sds = model.abstract_cache(B, S, jnp.bfloat16)
        c_sh = cache_shardings(mesh, cache_sds, batch=B)
        text_len = S - (cfg.frontend_tokens if cfg.frontend else 0)
        inputs_sds: Dict[str, Any] = {
            "tokens": jax.ShapeDtypeStruct((B, text_len), jnp.int32)}
        fe = _frontend_sds(cfg, B)
        if fe is not None:
            inputs_sds["frontend_embeds"] = fe
        i_sh = batch_shardings(mesh, inputs_sds, batch=B)
        return Cell(
            arch=arch, shape=shape, fn=model.prefill,
            args=(params_sds, inputs_sds, cache_sds),
            in_shardings=(p_sh, i_sh, c_sh),
            donate_argnums=(2,),
            model_cfg=cfg, entry="prefill",
            tokens_per_step=B * S, opts=opts,
        )

    # decode: one new token against a cache of length S
    cache_sds = model.abstract_cache(B, S, jnp.bfloat16)
    c_sh = cache_shardings(mesh, cache_sds, batch=B,
                           seq_shard="kv_seq_shard" in opts)
    tok_sds = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    t_sh = batch_shardings(mesh, tok_sds, batch=B)

    def serve_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens)

    return Cell(
        arch=arch, shape=shape, fn=serve_step,
        args=(params_sds, cache_sds, tok_sds),
        in_shardings=(p_sh, c_sh, t_sh),
        donate_argnums=(1,),
        model_cfg=cfg, entry="serve_step",
        tokens_per_step=B, opts=opts,
    )
