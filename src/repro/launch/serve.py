"""Serving driver CLI: run any registry architecture through the engine in
any execution mode.

    # GPU-free emulated evaluation of a 70B deployment:
    PYTHONPATH=src python -m repro.launch.serve --arch llama3_70b \
        --mode emulate --tp 4 --qps 2 --num-requests 100

    # strawman sleep-based emulation (paper §3.2):
    PYTHONPATH=src python -m repro.launch.serve --arch llama3_8b --mode sleep

    # actually execute a reduced model on CPU (ground truth):
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2_5_3b --mode real
"""

from __future__ import annotations

import argparse
import json


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_8b")
    ap.add_argument("--mode", default="emulate",
                    choices=["emulate", "sleep", "real"])
    ap.add_argument("--policy", default="vllm", choices=["vllm", "sglang"])
    ap.add_argument("--chip", default="h200-sxm")
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--pp", type=int, default=1)
    ap.add_argument("--ep", type=int, default=1)
    ap.add_argument("--max-num-seqs", type=int, default=64)
    ap.add_argument("--chunk", type=int, default=512,
                    help="max batched tokens (chunked-prefill budget)")
    ap.add_argument("--num-requests", type=int, default=100)
    ap.add_argument("--qps", type=float, default=2.0)
    ap.add_argument("--prompt-mean", type=float, default=220.0)
    ap.add_argument("--output-mean", type=float, default=180.0)
    ap.add_argument("--shared-prefix", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", action="store_true", help="machine output")
    args = ap.parse_args()

    from repro.configs import get_config, get_reduced_config
    from repro.serving.benchmark import BenchmarkRunner
    from repro.serving.scheduler import EngineConfig
    from repro.serving.stack import build_stack
    from repro.workload import WorkloadConfig, synthesize

    engine_cfg = EngineConfig(
        policy=args.policy, max_num_seqs=args.max_num_seqs,
        max_batched_tokens=args.chunk, block_size=16, num_blocks=32768,
        chip=args.chip, tp=args.tp, pp=args.pp, ep=args.ep)

    kw = {}
    if args.mode == "real":
        import jax
        import jax.numpy as jnp
        from repro.models.transformer import build_model
        model_cfg = get_reduced_config(args.arch)
        engine_cfg = EngineConfig(
            policy=args.policy, max_num_seqs=8, max_batched_tokens=64,
            block_size=4, num_blocks=4096)
        model = build_model(model_cfg)
        kw = dict(model=model,
                  params=model.init(jax.random.key(0), jnp.float32),
                  max_len=512, max_seqs=8)
        print(f"real mode: reduced {model_cfg.arch_id} "
              f"({model_cfg.param_count():,} params) executing on "
              f"{jax.default_backend()}")
    else:
        model_cfg = get_config(args.arch)

    stack = build_stack(model_cfg, engine_cfg, args.mode, **kw)
    wl = WorkloadConfig(
        num_requests=args.num_requests, qps=args.qps,
        prompt_len_mean=args.prompt_mean, output_len_mean=args.output_mean,
        shared_prefix_len=args.shared_prefix, seed=args.seed,
        **({"max_prompt_len": 96, "max_output_len": 16, "vocab_size": 500,
            "prompt_len_mean": 24, "output_len_mean": 8}
           if args.mode == "real" else {}))
    reqs = synthesize(wl)
    try:
        res = BenchmarkRunner(stack.engine, reqs,
                              transport=stack.transport).run(timeout=3600)
    finally:
        stack.shutdown()

    summary = dict(arch=args.arch, mode=args.mode, policy=args.policy,
                   **res.summary())
    if args.json:
        print(json.dumps(summary))
    else:
        for k, v in summary.items():
            print(f"  {k:24s} {v:,.3f}" if isinstance(v, float)
                  else f"  {k:24s} {v}")


if __name__ == "__main__":
    main()
