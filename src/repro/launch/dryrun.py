import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks the device count on first
#   init).  512 placeholder host devices back both the (16,16) single-pod
#   mesh (auto-subset of 256) and the (2,16,16) multi-pod mesh.

"""Multi-pod dry-run: lower + compile every (architecture × shape × mesh)
cell and extract the roofline raw material.

For each cell this produces (and appends to a JSONL artifact):

* ``memory_analysis``  — per-device argument/output/temp bytes (proves fit),
* ``cost_analysis``    — per-device HLO FLOPs + bytes accessed,
* ``collective_bytes`` — parsed from the post-SPMD HLO: summed per-device
  tensor bytes of all-reduce / all-gather / reduce-scatter / all-to-all /
  collective-permute ops (cost_analysis does not report these),
* compile wall time and the collective-op census.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2_5_3b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all            # every cell
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod

``--all`` runs each cell in a subprocess so XLA compiler state cannot leak
across cells (and one failure doesn't kill the sweep).
"""

import argparse
import json
import re
import subprocess
import sys
import time
from pathlib import Path

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+(?:[0-9]+)?)\[([0-9,]*)\]")


def parse_collective_bytes(hlo_text: str) -> dict:
    """Sum per-device result bytes of every collective op in post-SPMD HLO."""
    totals = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        op = None
        for cand in _COLLECTIVES:
            # match " = <shape> all-reduce(" or tuple-shaped results
            if f" {cand}(" in stripped or f"{cand}-start(" in stripped:
                op = cand
                break
        if op is None or "=" not in stripped:
            continue
        lhs = stripped.split("=", 1)[1]
        lhs = lhs.split(op, 1)[0]
        nbytes = 0
        for dtype, dims in _SHAPE_RE.findall(lhs):
            if dtype not in _DTYPE_BYTES:
                continue
            n = 1
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dtype]
        totals[op] += nbytes
        counts[op] += 1
    return {
        "bytes_by_op": totals,
        "counts_by_op": counts,
        "total_bytes": sum(totals.values()),
        "total_ops": sum(counts.values()),
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_path: str,
             verbose: bool = True, opts: tuple = ()) -> dict:
    import jax

    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import build_cell

    mesh = make_production_mesh(multi_pod=multi_pod)
    cell = build_cell(arch, shape_name, mesh, opts=opts)

    t0 = time.time()
    with mesh:
        jf = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                     donate_argnums=cell.donate_argnums)
        lowered = jf.lower(*cell.args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    if verbose:
        print(compiled.memory_analysis())   # proves it fits
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):     # jax 0.4.x: one dict per device
        cost = cost[0] if cost else {}
    if verbose:
        print({k: cost[k] for k in ("flops", "bytes accessed")
               if k in cost})
    hlo_text = compiled.as_text()
    coll = parse_collective_bytes(hlo_text)
    # Trip-count-aware costs: XLA's cost_analysis counts while (scan) bodies
    # exactly once, underreporting scan-heavy programs by the trip count —
    # repro.launch.hlo_cost re-derives flops/bytes/collective bytes with
    # loop multipliers from the compiled module's known_trip_count configs.
    from repro.launch.hlo_cost import analyze_hlo
    tc = analyze_hlo(hlo_text)

    chips = 1
    for d in mesh.devices.shape:
        chips *= d
    record = {
        "arch": arch,
        "shape": shape_name,
        "opts": sorted(cell.opts),
        "entry": cell.entry,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "axes": list(mesh.axis_names),
        "chips": chips,
        "tokens_per_step": cell.tokens_per_step,
        "model_params": cell.model_cfg.param_count(),
        "model_active_params": cell.model_cfg.active_param_count(),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_per_device": (mem.argument_size_in_bytes
                                + mem.temp_size_in_bytes),
        },
        # trip-count-aware (authoritative for §Roofline):
        "cost": {
            "flops": tc.flops,
            "bytes_accessed": tc.bytes,
        },
        "collectives": {
            "bytes_by_op": tc.collective_bytes_by_op,
            "total_bytes": tc.collective_bytes,
            "total_ops": tc.collective_ops,
            "while_loops": tc.while_loops,
            "unknown_trip_loops": tc.unknown_trip_loops,
        },
        # raw single-visit numbers, for reference (scan bodies counted once):
        "xla_cost": {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        },
        "collectives_flat": coll,
    }
    if out_path:
        with open(out_path, "a") as f:
            f.write(json.dumps(record) + "\n")
    if verbose:
        print(json.dumps({k: record[k] for k in
                          ("arch", "shape", "mesh", "compile_s")}))
    return record


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--opt", default="",
                    help="comma-separated perf knobs (see specs.build_cell)")
    ap.add_argument("--all", action="store_true",
                    help="run every applicable (arch x shape) cell")
    ap.add_argument("--out", default="benchmarks/artifacts/dryrun.jsonl")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    Path(args.out).parent.mkdir(parents=True, exist_ok=True)

    opts = tuple(o for o in args.opt.split(",") if o)
    if not args.all:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        run_cell(args.arch, args.shape, args.multi_pod, args.out, opts=opts)
        return

    # sweep: one subprocess per cell for isolation
    from repro.configs import all_cells
    done = set()
    if args.skip_existing and Path(args.out).exists():
        for line in Path(args.out).read_text().splitlines():
            r = json.loads(line)
            done.add((r["arch"], r["shape"], r["mesh"]))
    mesh_tag = "2x16x16" if args.multi_pod else "16x16"
    cells = all_cells()
    failures = []
    for i, (arch, shape) in enumerate(cells):
        if (arch, shape, mesh_tag) in done:
            print(f"[{i+1}/{len(cells)}] {arch} x {shape} — cached")
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--out", args.out]
        if args.multi_pod:
            cmd.append("--multi-pod")
        print(f"[{i+1}/{len(cells)}] {arch} x {shape} ({mesh_tag}) ...",
              flush=True)
        t0 = time.time()
        proc = subprocess.run(cmd, capture_output=True, text=True)
        if proc.returncode != 0:
            failures.append((arch, shape))
            print(f"  FAILED ({time.time()-t0:.0f}s):\n{proc.stderr[-2000:]}")
        else:
            print(f"  ok ({time.time()-t0:.0f}s)")
    if failures:
        print("FAILURES:", failures)
        sys.exit(1)
    print(f"all {len(cells)} cells passed on {mesh_tag}")


if __name__ == "__main__":
    main()
