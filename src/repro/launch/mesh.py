"""Production mesh + partitioning rules for every architecture × shape.

Mesh geometry (assignment-mandated):

* single pod:  (16, 16)      axes ("data", "model")      — 256 chips
* multi-pod:   (2, 16, 16)   axes ("pod", "data", "model") — 512 chips

Sharding strategy (name-based rules with divisibility fallback — a dimension
is sharded only when it divides evenly; otherwise the rule falls through to
the next candidate dimension or replication):

* **TP ("model")**: attention heads (falling back to head_dim when the head
  count doesn't divide, e.g. whisper 8H / recurrentgemma 10H), FFN width,
  MoE expert dim (dbrx 16e, qwen3 128e; mixtral's 8e falls back to expert-FFN
  width), vocab for embeddings, SSD inner width.
* **FSDP ("data" [+ "pod"])** — training only: parameter + optimizer-state
  dim sharded over the batch axes (ZeRO-3; XLA inserts per-layer
  all-gathers).  Serving replicates dense weights across "data" (weights are
  read-only and latency-critical) except MoE expert tensors, which stay
  data-sharded so dbrx-132B fits 16 GB chips.
* **Batch ("pod"+"data")**: token batches, KV caches, recurrent states.
  ``long_500k`` (batch=1) shards the KV *sequence* dim over "data" instead
  (context-parallel decode).

All rules are *right-aligned* on trailing dimensions, so the same table
serves stacked scan parameters (L, ...), unstacked per-layer trees, and
cache pytrees.
"""

from __future__ import annotations

import re
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

__all__ = [
    "make_production_mesh",
    "param_shardings",
    "cache_shardings",
    "batch_shardings",
    "MeshAxes",
]


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


class MeshAxes:
    """Resolved axis names/sizes for a mesh (pod axis optional)."""

    def __init__(self, mesh: Mesh):
        self.mesh = mesh
        self.names = mesh.axis_names
        self.sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        self.model = "model" if "model" in self.names else None
        self.data = "data" if "data" in self.names else None
        self.pod = "pod" if "pod" in self.names else None

    @property
    def batch_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in (self.pod, self.data) if a)

    def batch_size(self) -> int:
        n = 1
        for a in self.batch_axes:
            n *= self.sizes[a]
        return n

    def model_size(self) -> int:
        return self.sizes.get("model", 1)


def _divides(dim: int, n: int) -> bool:
    return n > 0 and dim % n == 0


class _SpecBuilder:
    """Builds a PartitionSpec right-aligned on a concrete shape, assigning
    each mesh axis at most once and only onto evenly-divisible dims."""

    def __init__(self, shape: Sequence[int], ax: MeshAxes):
        self.shape = tuple(shape)
        self.ax = ax
        self.spec: list = [None] * len(shape)
        self.used: set = set()

    def try_assign(self, pos: int, axis) -> bool:
        """pos: negative index from the right.  axis: name or tuple."""
        if axis is None:
            return False
        axes = (axis,) if isinstance(axis, str) else tuple(axis)
        axes = tuple(a for a in axes if a and a not in self.used)
        if not axes:
            return False
        idx = len(self.shape) + pos
        if idx < 0 or self.spec[idx] is not None:
            return False
        total = 1
        for a in axes:
            total *= self.ax.sizes[a]
        if not _divides(self.shape[idx], total):
            return False
        self.spec[idx] = axes[0] if len(axes) == 1 else axes
        self.used.update(axes)
        return True

    def first(self, candidates) -> None:
        """Assign the first workable (pos, axis) candidate."""
        for pos, axis in candidates:
            if self.try_assign(pos, axis):
                return

    def build(self) -> P:
        return P(*self.spec)


def _leaf_name(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
    return "/".join(parts)


# --------------------------------------------------------------------------
# parameter rules
# --------------------------------------------------------------------------

def _param_spec(name: str, shape: Tuple[int, ...], ax: MeshAxes,
                mode: str) -> P:
    """mode: 'train' (FSDP over batch axes) or 'serve' (dense replicated)."""
    b = _SpecBuilder(shape, ax)
    model = ax.model
    fsdp = ax.batch_axes if mode == "train" else ()
    fsdp = fsdp if fsdp else None
    leaf = name.rsplit("/", 1)[-1]
    nd = len(shape)

    is_moe = "/moe/" in name or name.endswith(("moe/w_in", "moe/w_out"))
    moe_data = ax.batch_axes or None      # MoE experts: data-shard even in serve

    if leaf in ("embed", "unembed"):
        # embed (V, d) / unembed (d, V): shard vocab over model, other over fsdp
        vpos = -2 if leaf == "embed" else -1
        dpos = -1 if leaf == "embed" else -2
        b.first([(vpos, model)])
        b.first([(dpos, fsdp)])
    elif leaf == "pos_embed":
        pass  # replicate
    elif leaf in ("wq", "wk", "wv"):       # (d, H, Dh)
        b.first([(-2, model), (-1, model)])
        b.first([(-3, fsdp)])
    elif leaf == "wo" and nd >= 3 and "attn" in name:   # (H, Dh, d)
        b.first([(-3, model), (-2, model)])
        b.first([(-1, fsdp)])
    elif leaf in ("wi", "wg"):             # (d, F)
        b.first([(-1, model)])
        b.first([(-2, fsdp)])
    elif leaf == "wo":                     # mlp (F, d)
        b.first([(-2, model)])
        b.first([(-1, fsdp)])
    elif leaf == "router":                 # (d, E)
        b.first([(-2, fsdp)])
    elif leaf == "w_in" and is_moe:        # (E, d, n*ff)
        if not b.try_assign(-3, model):    # EP when expert count divides
            b.first([(-1, model)])
        b.first([(-2, moe_data)])
    elif leaf == "w_out" and is_moe:       # (E, ff, d)
        if not b.try_assign(-3, model):
            b.first([(-2, model)])
        b.first([(-1, moe_data)])
    elif leaf == "w_in":                   # ssd (d, X)
        b.first([(-1, model)])
        b.first([(-2, fsdp)])
    elif leaf == "w_out":                  # ssd/rglru (w, d)
        b.first([(-2, model)])
        b.first([(-1, fsdp)])
    elif leaf in ("w_x", "w_gate_in"):     # rglru (d, w)
        b.first([(-1, model)])
        b.first([(-2, fsdp)])
    elif leaf in ("w_a", "w_i"):           # rglru gates (w, w)
        b.first([(-1, model)])
        b.first([(-2, fsdp)])
    # conv kernels, norms, biases, Λ/A_log/D/dt_bias: replicated
    return b.build()


def param_shardings(mesh: Mesh, params_tree: Any, mode: str = "train") -> Any:
    """NamedSharding tree matching ``params_tree`` (arrays or SDS)."""
    ax = MeshAxes(mesh)

    def one(path, leaf):
        spec = _param_spec(_leaf_name(path), leaf.shape, ax, mode)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_tree)


# --------------------------------------------------------------------------
# cache rules
# --------------------------------------------------------------------------

def _cache_spec(name: str, shape: Tuple[int, ...], ax: MeshAxes,
                *, shard_batch: bool, seq_shard: bool = False) -> P:
    b = _SpecBuilder(shape, ax)
    model = ax.model
    batch = ax.batch_axes or None
    leaf = name.rsplit("/", 1)[-1]
    if leaf in ("k", "v", "cross_k", "cross_v"):   # (..., B, S, H, D)
        if shard_batch:
            b.first([(-4, batch)])
        else:
            b.first([(-3, ax.data)])               # context parallel on seq
        if seq_shard:
            # §Perf "kv_seq_shard": decode contexts sharded over "model" on
            # the sequence dim.  The score contraction then stays local per
            # S-shard; only the softmax max/sum statistics and the (B,H,D)
            # output partial-sums cross chips — O(B·H·D) instead of the
            # O(B·H·S) per-layer score all-reduce that head_dim sharding
            # forces (head_dim is the fallback when Hkv < |model|).
            b.first([(-3, model)])
        b.first([(-2, model), (-1, model)])
    elif leaf == "kv_pos":                          # (..., B, S)
        if shard_batch:
            b.first([(-2, batch)])
        else:
            b.first([(-1, ax.data)])
        if seq_shard:
            b.first([(-1, model)])
    elif leaf == "state" and len(shape) >= 4:       # ssd (..., B, H, N, P)
        if shard_batch:
            b.first([(-4, batch)])
        b.first([(-3, model)])
    elif leaf == "state":                           # rglru (..., B, W)
        if shard_batch:
            b.first([(-2, batch)])
        b.first([(-1, model)])
    elif leaf == "conv":                            # (..., B, K-1, C)
        if shard_batch:
            b.first([(-3, batch)])
        b.first([(-1, model)])
    elif leaf == "cache_len":                       # (B,)
        if shard_batch:
            b.first([(-1, batch)])
    return b.build()


def cache_shardings(mesh: Mesh, cache_tree: Any, *, batch: int,
                    seq_shard: bool = False) -> Any:
    ax = MeshAxes(mesh)
    shard_batch = _divides(batch, ax.batch_size())

    def one(path, leaf):
        spec = _cache_spec(_leaf_name(path), leaf.shape, ax,
                           shard_batch=shard_batch, seq_shard=seq_shard)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, cache_tree)


# --------------------------------------------------------------------------
# batch (token input) rules
# --------------------------------------------------------------------------

def batch_shardings(mesh: Mesh, batch_tree: Any, *, batch: int) -> Any:
    """tokens/labels (B, S); frontend_embeds (B, F, d); positions (B, S)."""
    ax = MeshAxes(mesh)
    shard_batch = _divides(batch, ax.batch_size())

    def one(path, leaf):
        b = _SpecBuilder(leaf.shape, ax)
        if shard_batch and len(leaf.shape) >= 1:
            b.try_assign(-len(leaf.shape), ax.batch_axes)
        return NamedSharding(mesh, b.build())

    return jax.tree_util.tree_map_with_path(one, batch_tree)


def replicated(mesh: Mesh, tree: Any) -> Any:
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
