"""Device emulation layer: split-state memory model + collective semantics."""

import threading

import numpy as np
import pytest

from repro.core.emulation import (EmulatedChannel, EmulatedCollective,
                                  PhantomReadError, VirtualDeviceContext,
                                  VirtualOOMError)
from repro.core.hardware import TPU_V5E, get_chip


def test_split_state_thresholding():
    ctx = VirtualDeviceContext(2, TPU_V5E)
    meta = ctx.malloc(1024, 0, tag="block_table")
    big = ctx.malloc(512 << 20, 1, tag="kv_pool")
    # metadata: faithful read/write
    meta.write(np.arange(16, dtype=np.uint8))
    assert meta.read(4).tolist() == [0, 1, 2, 3]
    # compute buffer: writes are accounted no-ops, reads FAULT
    big.write(None)
    assert big.writes == 1
    with pytest.raises(PhantomReadError):
        big.read()


def test_virtual_oom_is_a_prediction():
    ctx = VirtualDeviceContext(1, TPU_V5E)
    ctx.malloc(int(10e9), 0, tag="weights")
    with pytest.raises(VirtualOOMError):
        ctx.malloc(int(8e9), 0, tag="kv")       # 18 GB > 16 GB HBM
    # freeing restores capacity
    b = ctx.malloc(int(4e9), 0, tag="kv-small")
    ctx.free(b)
    ctx.malloc(int(5.9e9), 0, tag="kv-again")


def test_double_free_detected():
    ctx = VirtualDeviceContext(1, TPU_V5E)
    b = ctx.malloc(1 << 20, 0)
    ctx.free(b)
    with pytest.raises(RuntimeError):
        ctx.free(b)


def test_memory_report_peaks():
    ctx = VirtualDeviceContext(2, TPU_V5E)
    a = ctx.malloc(1 << 30, 0)
    ctx.free(a)
    ctx.malloc(1 << 20, 0)
    rep = ctx.memory_report()
    assert rep["per_device_peak_bytes"][0] == 1 << 30
    assert rep["per_device_live_bytes"][0] == 1 << 20


def test_collective_exit_is_max_plus_duration():
    coll = EmulatedCollective(3, "ar")
    outs = {}

    def worker(i, t, d):
        outs[i] = coll.arrive(t, d)

    ts = [threading.Thread(target=worker, args=(i, t, d))
          for i, (t, d) in enumerate([(1.0, 0.1), (2.0, 0.1), (1.5, 0.1)])]
    for t in ts: t.start()
    for t in ts: t.join()
    assert all(v == pytest.approx(2.1) for v in outs.values())


def test_collective_straggler_timeout():
    coll = EmulatedCollective(2, "ar")
    with pytest.raises(TimeoutError):
        coll.arrive(0.0, 0.0, timeout=0.05)


def test_channel_transfer_time_and_order():
    ch = EmulatedChannel(bandwidth=100e9, name="kv")
    ch.send("req-1", t_virtual=5.0, nbytes=int(1e9))     # 10 ms transfer
    ch.send("req-2", t_virtual=6.0, nbytes=0)
    p1, t1 = ch.recv()
    p2, t2 = ch.recv()
    assert p1 == "req-1" and t1 == pytest.approx(5.01)
    assert p2 == "req-2" and t2 == pytest.approx(6.0)


def test_chip_registry():
    assert get_chip("tpu-v5e").peak_flops_bf16 == pytest.approx(197e12)
    with pytest.raises(KeyError):
        get_chip("tpu-v9")
