"""Socket transport: the multi-process Timekeeper deployment (paper §5).

Exercises fan-in/fan-out over real TCP, replica-clock consistency, and the
fault-tolerance path: a dying connection deregisters its actors so the
barrier is never wedged by a crashed worker.
"""

import threading
import time

import pytest

from repro.core.client import TimeJumpClient
from repro.core.transport import SocketTransport, TimekeeperServer


@pytest.fixture()
def server():
    srv = TimekeeperServer(jitter_cooldown=0.0)
    yield srv
    srv.close()


def test_remote_jump_roundtrip(server):
    tr = SocketTransport(server.address)
    c = TimeJumpClient(tr, "remote-a")
    t0 = c.now()
    t1 = c.time_jump(0.2)
    assert t1 >= t0 + 0.2 - 1e-6
    c.deregister()
    tr.close()


def test_two_remote_clients_coordinate(server):
    tra = SocketTransport(server.address)
    trb = SocketTransport(server.address)
    a = TimeJumpClient(tra, "A")
    b = TimeJumpClient(trb, "B")
    results = {}

    def run(name, client, dt, n):
        t0 = time.monotonic()
        for _ in range(n):
            client.time_jump(dt)
        results[name] = time.monotonic() - t0

    ta = threading.Thread(target=run, args=("A", a, 0.05, 10))
    tb = threading.Thread(target=run, args=("B", b, 0.025, 20))
    ta.start(); tb.start(); ta.join(); tb.join()
    # 500 virtual ms coordinated across processes' worth of sockets in
    # far less wall time than sleeping would need
    assert max(results.values()) < 0.4, results
    # replica clocks agree with the server's
    assert abs(tra.clock.now() - trb.clock.now()) < 0.05
    a.deregister(); b.deregister()
    tra.close(); trb.close()


def test_dead_connection_releases_barrier(server):
    """Kill a client's socket mid-registration: the server must deregister
    its actors so the survivor's jump completes by barrier (fast), not by
    degradation timeout."""
    tra = SocketTransport(server.address)
    trb = SocketTransport(server.address)
    a = TimeJumpClient(tra, "survivor")
    b = TimeJumpClient(trb, "casualty")

    done = threading.Event()

    def run_a():
        a.time_jump(5.0)        # would take 5 wall seconds if degraded
        done.set()

    t = threading.Thread(target=run_a)
    t.start()
    time.sleep(0.05)
    assert not done.is_set()
    trb.close()                  # crash the casualty's process
    t.join(timeout=3.0)
    assert done.is_set(), "survivor stayed wedged after peer death"
    tra.close()


def test_observer_time_query(server):
    tr = SocketTransport(server.address)
    c = TimeJumpClient(tr, "actor")
    c.time_jump(1.0)
    tro = SocketTransport(server.address)   # pure observer connection
    t = tro.observer_time()
    assert t >= 1.0 - 1e-6 + (tr.clock.now() - tr.clock.now())  # sane
    assert abs(t - tr.clock.now()) < 0.05
    c.deregister()
    tr.close(); tro.close()
