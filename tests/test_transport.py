"""Socket transport: the multi-process Timekeeper deployment (paper §5).

Exercises fan-in/fan-out over real TCP, replica-clock consistency, the
park/unpark frames, and the fault-tolerance paths: a dying connection
deregisters its actors (parked ones included) so the barrier is never
wedged by a crashed worker; server close releases remote waiters through a
final broadcast; an unresponsive server surfaces as TransportClosed after
the RPC timeout instead of blocking an actor forever.
"""

import socket
import threading
import time

import pytest

from repro.core.client import TimeJumpClient
from repro.core.transport import (SocketTransport, TimekeeperServer,
                                  TransportClosed)

# Socket tests must never hang the suite: pytest-timeout enforces this in
# CI (the marker is registered in pytest.ini, so it is inert-but-silent
# when the plugin is absent locally).
pytestmark = pytest.mark.timeout(120)


@pytest.fixture()
def server():
    srv = TimekeeperServer(jitter_cooldown=0.0)
    yield srv
    srv.close()


def test_remote_jump_roundtrip(server):
    tr = SocketTransport(server.address)
    c = TimeJumpClient(tr, "remote-a")
    t0 = c.now()
    t1 = c.time_jump(0.2)
    assert t1 >= t0 + 0.2 - 1e-6
    c.deregister()
    tr.close()


def test_two_remote_clients_coordinate(server):
    tra = SocketTransport(server.address)
    trb = SocketTransport(server.address)
    a = TimeJumpClient(tra, "A")
    b = TimeJumpClient(trb, "B")
    results = {}

    def run(name, client, dt, n):
        t0 = time.monotonic()
        for _ in range(n):
            client.time_jump(dt)
        results[name] = time.monotonic() - t0

    ta = threading.Thread(target=run, args=("A", a, 0.05, 10))
    tb = threading.Thread(target=run, args=("B", b, 0.025, 20))
    ta.start(); tb.start(); ta.join(); tb.join()
    # 500 virtual ms coordinated across processes' worth of sockets in
    # far less wall time than sleeping would need
    assert max(results.values()) < 0.4, results
    # replica clocks agree with the server's
    assert abs(tra.clock.now() - trb.clock.now()) < 0.05
    a.deregister(); b.deregister()
    tra.close(); trb.close()


def test_dead_connection_releases_barrier(server):
    """Kill a client's socket mid-registration: the server must deregister
    its actors so the survivor's jump completes by barrier (fast), not by
    degradation timeout."""
    tra = SocketTransport(server.address)
    trb = SocketTransport(server.address)
    a = TimeJumpClient(tra, "survivor")
    b = TimeJumpClient(trb, "casualty")

    done = threading.Event()

    def run_a():
        a.time_jump(5.0)        # would take 5 wall seconds if degraded
        done.set()

    t = threading.Thread(target=run_a)
    t.start()
    time.sleep(0.05)
    assert not done.is_set()
    trb.close()                  # crash the casualty's process
    t.join(timeout=3.0)
    assert done.is_set(), "survivor stayed wedged after peer death"
    tra.close()


def test_observer_time_query(server):
    tr = SocketTransport(server.address)
    c = TimeJumpClient(tr, "actor")
    c.time_jump(1.0)
    tro = SocketTransport(server.address)   # pure observer connection
    t = tro.observer_time()
    assert t >= 1.0 - 1e-6 + (tr.clock.now() - tr.clock.now())  # sane
    assert abs(t - tr.clock.now()) < 0.05
    c.deregister()
    tr.close(); tro.close()


# =========================================================================
# park/unpark over the wire
# =========================================================================

def test_remote_park_excluded_from_barrier(server):
    """A parked remote replica must not stall barrier rounds: the survivor's
    jump resolves immediately (barrier of one), and unparking re-joins."""
    tra = SocketTransport(server.address)
    trb = SocketTransport(server.address)
    a = TimeJumpClient(tra, "busy")
    b = TimeJumpClient(trb, "idle-replica")
    b.park()
    assert server.timekeeper.num_actors == 1
    assert server.timekeeper.num_parked == 1

    t0 = time.monotonic()
    a.time_jump(2.0)                     # would be 2 wall seconds if stalled
    assert time.monotonic() - t0 < 0.5, "parked remote replica stalled round"

    b.unpark()
    assert server.timekeeper.num_actors == 2
    # both must now arrive for a round to resolve
    done = threading.Event()

    def jump_a():
        a.time_jump(0.2)
        done.set()

    t = threading.Thread(target=jump_a)
    t.start()
    time.sleep(0.05)
    assert not done.is_set(), "round resolved without the unparked replica"
    b.time_jump(0.2)
    t.join(timeout=3.0)
    assert done.is_set()
    a.deregister(); b.deregister()
    tra.close(); trb.close()


def test_park_when_mid_barrier_request_pending(server):
    """Parking an actor whose peer has a *pending* jump re-evaluates the
    barrier (the park path of _maybe_resolve_locked) — no wedge."""
    tra = SocketTransport(server.address)
    trb = SocketTransport(server.address)
    a = TimeJumpClient(tra, "requester")
    b = TimeJumpClient(trb, "parker")
    done = threading.Event()

    def jump_a():
        a.time_jump(3.0)
        done.set()

    t = threading.Thread(target=jump_a)
    t.start()
    time.sleep(0.05)
    assert not done.is_set()
    b.park()                              # barrier shrinks to {a}: resolves
    t.join(timeout=3.0)
    assert done.is_set(), "park never re-evaluated the barrier"
    a.deregister(); b.deregister()
    tra.close(); trb.close()


# =========================================================================
# failure paths: none may wedge the Timekeeper
# =========================================================================

def test_client_disconnect_mid_barrier_releases_peers(server):
    """The casualty dies mid-run — after participating in rounds, while the
    survivor is mid-multi-round jump and barred on it: connection teardown
    must deregister the casualty and resolve the survivor's round."""
    tra = SocketTransport(server.address)
    trb = SocketTransport(server.address)
    a = TimeJumpClient(tra, "survivor")
    b = TimeJumpClient(trb, "casualty")
    done = threading.Event()

    def jump_a():
        a.time_jump(5.0)
        done.set()

    ta = threading.Thread(target=jump_a)
    ta.start()
    time.sleep(0.05)
    b.time_jump(0.2)              # one joint round resolves (to b's target);
    time.sleep(0.1)               # a re-requests and is now barred on b
    assert not done.is_set()
    trb.close()                   # crash: b must not pin the barrier
    ta.join(timeout=3.0)
    assert done.is_set(), "survivor stayed wedged after mid-barrier death"
    assert server.timekeeper.num_actors == 1
    a.deregister()
    tra.close()


def test_server_close_with_parked_actors_releases_everyone(server):
    """close() with a parked remote actor and a waiter mid-jump: the final
    broadcast releases the waiter promptly (no degradation-timeout ride),
    parked state is dropped, and later RPCs fail fast instead of hanging."""
    tra = SocketTransport(server.address)
    trb = SocketTransport(server.address)
    a = TimeJumpClient(tra, "waiter")
    b = TimeJumpClient(trb, "parked")
    b.park()
    released = threading.Event()

    def jump_a():
        try:
            a.time_jump(30.0)     # would be 30 wall seconds if degraded
        except (TransportClosed, KeyError):
            pass
        released.set()

    t = threading.Thread(target=jump_a)
    t.start()
    time.sleep(0.05)
    server.close()
    t.join(timeout=5.0)
    assert released.is_set(), \
        "waiter rode out its degradation timeout after server close"
    assert server.timekeeper.num_actors == 0
    assert server.timekeeper.num_parked == 0
    with pytest.raises((TransportClosed, KeyError)):
        tra.observer_time()
    tra.close(); trb.close()


def test_jump_request_timeout_surfaces_not_wedges():
    """A server that accepts but never replies: the jump RPC must raise
    TransportClosed after rpc_timeout — the actor thread is released (the
    replica clock kept flowing at wall rate meanwhile, so no correctness
    loss) instead of blocking forever."""
    mute = socket.create_server(("127.0.0.1", 0))
    try:
        tr = SocketTransport(mute.getsockname(), rpc_timeout=0.2)
        t0 = time.monotonic()
        with pytest.raises(TransportClosed):
            tr.send_jump_request("actor", 1.0)
        assert time.monotonic() - t0 < 2.0
        tr.close()
    finally:
        mute.close()


def test_rpc_after_server_death_fails_fast(server):
    """Pending and subsequent RPCs fail promptly when the server socket
    dies, and a real Timekeeper behind a *different* live server keeps
    working (the failure is scoped to the dead transport)."""
    tr = SocketTransport(server.address)
    c = TimeJumpClient(tr, "lonely")
    c.time_jump(0.1)
    server.close()
    time.sleep(0.1)               # reader notices the close
    with pytest.raises((TransportClosed, KeyError)):
        tr.send_jump_request("lonely", 99.0)
    tr.close()

    other = TimekeeperServer(jitter_cooldown=0.0)
    try:
        tr2 = SocketTransport(other.address)
        c2 = TimeJumpClient(tr2, "alive")
        assert c2.time_jump(0.05) > 0
        c2.deregister()
        tr2.close()
    finally:
        other.close()
