"""Repo hygiene: no bytecode — tracked OR on disk — under ``src/``.

A stale ``.pyc`` silently shadows the source edit you are testing: Python
trusts the cached file when mtimes line up, which they do after checkouts
and branch switches.  CI already rejects *tracked* bytecode; this tier-1
test extends the guard to *untracked* ``__pycache__`` dirs sitting in the
working tree (they are gitignored, so nothing else ever complains about
them).  The root ``conftest.py`` keeps the test run itself from writing
any, so a failure here always points at an outside invocation — fix with
``find src -name __pycache__ -exec rm -rf {} +`` and export
``PYTHONDONTWRITEBYTECODE=1`` in the offending workflow.
"""

import subprocess
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def test_no_tracked_bytecode():
    out = subprocess.run(
        ["git", "ls-files"], cwd=REPO, check=True,
        capture_output=True, text=True,
    ).stdout
    tracked = [line for line in out.splitlines()
               if line.endswith(".pyc") or "__pycache__/" in line]
    assert not tracked, f"bytecode committed to git: {tracked}"


def test_no_stale_bytecode_on_disk_under_src():
    stale = sorted(str(p.relative_to(REPO))
                   for p in (REPO / "src").rglob("__pycache__"))
    assert not stale, (
        f"stale bytecode dirs under src/ (these shadow source edits): "
        f"{stale} — remove with: find src -name __pycache__ "
        f"-exec rm -rf {{}} +"
    )
