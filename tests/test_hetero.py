"""Heterogeneous replica pools: tier specs, tier-aware routing, cost
accounting, tier-selecting autoscaling, and mixed-pool emulator-vs-DES
parity.

Determinism methodology matches tests/test_cluster.py: ManualWallSource runs
advance virtual time only through Timekeeper-coordinated jumps, so
mixed-tier timelines are exactly reproducible from their seed — the basis of
the byte-identical-metrics regression below.
"""

import copy

import pytest

from repro.cluster import (Autoscaler, AutoscalerConfig, QueueDepthPolicy,
                           SchedulePolicy, TierSpec, TTFTSLOPolicy,
                           build_cluster, drain_victim, make_router,
                           make_tier_specs, probe_throughput,
                           provision_delay, tier_engine_cfg)
from repro.configs import get_config, get_reduced_config
from repro.core.clock import ManualWallSource
from repro.core.hardware import get_chip
from repro.core.predictor import StaticPredictor
from repro.des.simulator import DESConfig, DiscreteEventSimulator
from repro.serving.benchmark import BenchmarkRunner
from repro.serving.scheduler import EngineConfig
from repro.workload import (SessionConfig, SessionWorkload, WorkloadConfig,
                            synthesize)

MODEL = get_reduced_config("qwen2_5_3b")
# per-tier predictors: the h100 tier steps 2.5x faster than the l4 tier
DT = {"h100": 5e-3, "l4": 12.5e-3}


def tier_predictors():
    return {t: StaticPredictor(s) for t, s in DT.items()}


def engine_cfg(**kw):
    base = dict(policy="vllm", max_num_seqs=8, max_batched_tokens=64,
                block_size=4, num_blocks=4096)
    base.update(kw)
    return EngineConfig(**base)


def tier_specs(ecfg=None):
    return make_tier_specs(MODEL, ecfg or engine_cfg(), list(DT),
                           tier_predictors=tier_predictors())


def build(tiers, policy="round_robin", ecfg=None, **kw):
    ecfg = ecfg or engine_cfg()
    return build_cluster(MODEL, ecfg, len(tiers), policy=policy,
                         tiers=list(tiers),
                         tier_predictors=tier_predictors(),
                         tier_specs=tier_specs(ecfg),
                         wall=ManualWallSource(), **kw)


def workload(n=16, qps=40.0, seed=3, **kw):
    base = dict(num_requests=n, qps=qps, prompt_len_mean=24,
                output_len_mean=8, max_prompt_len=48, max_output_len=12,
                seed=seed)
    base.update(kw)
    return synthesize(WorkloadConfig(**base))


# =========================================================================
# tier arithmetic units
# =========================================================================

def test_chip_aliases_and_costs():
    assert get_chip("h100") is get_chip("h100-sxm")
    assert get_chip("l4").name == "l4"
    assert get_chip("l4").cost_per_hour < get_chip("a100").cost_per_hour \
        < get_chip("h100").cost_per_hour
    assert get_chip("h100").cost_per_second == pytest.approx(5.5 / 3600)
    with pytest.raises(KeyError):
        get_chip("gpu-from-the-future")


def test_tier_engine_cfg_kv_capacity_reflects_chip():
    # a base config demanding ~42 GB of KV (20000 blocks x 16 tok x 128 KB):
    # fits the h100 (stays at the configured ceiling) but not the l4
    # (shrinks to what the chip holds after weights)
    model = get_config("llama3_8b")
    base = EngineConfig(block_size=16, num_blocks=20000)
    h100 = tier_engine_cfg(base, "h100", model)
    l4 = tier_engine_cfg(base, "l4", model)
    assert h100.chip == "h100" and l4.chip == "l4"
    assert h100.num_blocks == base.num_blocks
    assert 0 < l4.num_blocks < base.num_blocks
    # 70B weights (~141 GB bf16) cannot fit one l4 at all
    with pytest.raises(ValueError):
        tier_engine_cfg(base, "l4", get_config("llama3_70b"))


def test_tier_specs_from_predictors():
    specs = tier_specs()
    assert set(specs) == {"h100", "l4"}
    # throughput follows the per-tier step time (2.5x ratio), cost the chip
    ratio = specs["h100"].throughput_factor / specs["l4"].throughput_factor
    assert ratio == pytest.approx(DT["l4"] / DT["h100"])
    assert specs["l4"].cost_per_replica_s < specs["h100"].cost_per_replica_s
    assert specs["h100"].projected_ttft_s == pytest.approx(2 * DT["h100"])
    assert probe_throughput(StaticPredictor(0.01), batch=8) == 800.0


def test_ttft_slo_policy_selects_cheapest_feasible_tier():
    fast = TierSpec("h100", "h100-sxm", 5.5 / 3600, 800.0, 0.02)
    slow = TierSpec("l4", "l4", 0.8 / 3600, 200.0, 0.08)
    # loose SLO: both feasible, cheap one wins
    assert TTFTSLOPolicy(slo_ttft_s=0.5).select_tier(
        None, [fast, slow]).name == "l4"
    # tight SLO: only the fast tier projects to meet it
    assert TTFTSLOPolicy(slo_ttft_s=0.1).select_tier(
        None, [fast, slow]).name == "h100"
    # impossible SLO: fall back to the fastest tier
    assert TTFTSLOPolicy(slo_ttft_s=0.001).select_tier(
        None, [fast, slow]).name == "h100"
    # base policies default to cheapest
    assert QueueDepthPolicy().select_tier(None, [fast, slow]).name == "l4"


def test_provision_delay_per_tier():
    cfg = AutoscalerConfig(provision_delay_s=1.0,
                           provision_delay_by_tier={"l4": 0.25})
    assert provision_delay(cfg, "l4") == 0.25
    assert provision_delay(cfg, "h100") == 1.0
    assert provision_delay(cfg, None) == 1.0


# =========================================================================
# tier-aware routing units (fake views)
# =========================================================================

class FakeView:
    def __init__(self, tokens):
        self._t = tokens

    def outstanding_tokens(self):
        return self._t

    def prefix_match_len(self, tokens):
        return 0


def test_weighted_least_outstanding_normalizes_by_throughput():
    r = make_router("least_outstanding_tokens", 2)
    views = [FakeView(100), FakeView(40)]
    assert r.route(None, views) == 1          # unweighted: fewest tokens
    r.set_tier(0, weight=4.0, cost=1.0)       # replica 0 drains 4x faster
    assert r.route(None, views) == 0          # 100/4 < 40/1


def test_cost_normalized_load_prefers_cheap_tier():
    r = make_router("cost_normalized_load", 2)
    h100 = tier_specs()["h100"]
    l4 = tier_specs()["l4"]
    r.set_tier(0, weight=h100.throughput_factor, cost=h100.cost_per_replica_s)
    r.set_tier(1, weight=l4.throughput_factor, cost=l4.cost_per_replica_s)
    # equal (zero) load: the cheap tier wins the tie
    assert r.route(None, [FakeView(0), FakeView(0)]) == 1
    # the cheap tier is buried in backlog: the idle h100 wins despite price
    assert r.route(None, [FakeView(0), FakeView(5000)]) == 0
    # untiered (all costs 0): degrades to plain least-outstanding
    r2 = make_router("cost_normalized_load", 2)
    assert r2.route(None, [FakeView(10), FakeView(5)]) == 1


# =========================================================================
# satellite: mixed-tier routing determinism + drained-replica regression
# =========================================================================

def _session_workload(**kw):
    base = dict(num_sessions=6, qps=3.0, turns_mean=3.0, max_turns=4,
                think_time_mean=0.2, prompt_len_mean=30, followup_len_mean=10,
                output_len_mean=6, max_output_len=10, seed=7)
    base.update(kw)
    return SessionWorkload(SessionConfig(**base))


def test_mixed_tier_routing_byte_identical_across_runs():
    """Same seed + same tier mix ⇒ byte-identical metrics: the heterogeneous
    timeline is still a pure-jump deterministic computation."""

    def run_once():
        cluster = build(["h100", "l4"], policy="cost_normalized_load")
        try:
            res = BenchmarkRunner(cluster, _session_workload(),
                                  transport=cluster.transport).run(timeout=120)
            timeline = sorted(
                (r.session_id, r.turn_index, r.arrival_time,
                 r.first_token_time, r.finish_time)
                for r in cluster.finished)
            return (timeline, list(cluster.router.decisions),
                    res.cost_dollars, res.tier_seconds,
                    res.ttft, res.tpot)
        finally:
            cluster.shutdown()

    a, b = run_once(), run_once()
    assert a == b, "mixed-tier run is not byte-identical across same-seed runs"


def test_drained_cheap_replica_never_receives_new_sessions():
    """Regression: after the cheap tier drains out, no fresh request — not
    even a sticky-affinity session follow-up — may land on it."""
    sw = _session_workload(num_sessions=5, turns_mean=4.0, seed=11)
    cluster = build(["h100", "h100", "l4"], policy="prefix_affinity")
    try:
        cluster.start()
        victim = 2                             # the l4 replica
        # steer a couple of leading sessions through the l4 so the sticky
        # map points at it, then drain it mid-run
        first = sw.initial_requests()
        for r in first[:2]:
            cluster.engines[victim].prefix_match_len(r.prompt_tokens)
        res_runner = BenchmarkRunner(cluster, sw,
                                     transport=cluster.transport)
        # drain as soon as the first completions exist (inside the run):
        # registering the listener before run() keeps ordering simple
        drained_at_decision = []

        def drain_once(finished):
            if not drained_at_decision and victim in cluster.active:
                drained_at_decision.append(len(cluster.router.decisions))
                cluster.drain_replica(victim)

        cluster.add_completion_listener(drain_once)
        res_runner.run(timeout=120)
        cluster.remove_completion_listener(drain_once)
        assert drained_at_decision, "drain never happened"
        cut = drained_at_decision[0]
        late = cluster.router.decisions[cut:]
        assert late, "no routing decisions after the drain"
        assert all(d != victim for d in late), \
            f"drained l4 replica received new work: {late}"
        assert cluster.membership_events()[victim]["drained"] is not None
        assert len(cluster.finished) == sw.total_requests
    finally:
        cluster.shutdown()


# =========================================================================
# cost accounting
# =========================================================================

def test_replica_cost_and_tier_seconds_accounting():
    cluster = build(["h100", "l4"])
    specs = tier_specs()
    try:
        # static membership over a 3 s window
        assert cluster.tier_seconds(0.0, 3.0) == {"h100": 3.0, "l4": 3.0}
        expect = 3.0 * (specs["h100"].cost_per_replica_s
                        + specs["l4"].cost_per_replica_s)
        assert cluster.replica_cost(0.0, 3.0) == pytest.approx(expect)
        # l4 joined mid-window and drained before the end
        cluster._membership[1]["added"] = 1.0
        cluster._membership[1]["drained"] = 2.5
        assert cluster.replica_cost(0.0, 3.0) == pytest.approx(
            3.0 * specs["h100"].cost_per_replica_s
            + 1.5 * specs["l4"].cost_per_replica_s)
    finally:
        cluster.shutdown()


def test_untiered_cluster_costs_zero():
    cluster = build_cluster(MODEL, engine_cfg(), 2,
                            predictor=StaticPredictor(DT["h100"]),
                            wall=ManualWallSource())
    try:
        assert cluster.replica_cost(0.0, 5.0) == 0.0
        assert cluster.tier_seconds(0.0, 5.0) == {None: 10.0}
    finally:
        cluster.shutdown()


# =========================================================================
# tier-selecting autoscaler end-to-end
# =========================================================================

def test_autoscaler_scales_into_cheapest_tier():
    """Sustained overload on a lone h100: the queue-depth policy scales up
    and — with candidate tiers configured — provisions the cheap l4 (the
    default cheapest-candidate selection), recorded end to end: scaleups
    log, replica tier, engine chip, router weights, dollar cost."""
    reqs = workload(n=40, qps=60.0, output_len_mean=10)
    cluster = build(["h100"], policy="least_outstanding_tokens",
                    ecfg=engine_cfg(max_num_seqs=4))
    asc = Autoscaler(
        cluster, QueueDepthPolicy(target_depth=2.0),
        AutoscalerConfig(interval_s=0.02, provision_delay_s=0.05,
                         min_replicas=1, max_replicas=3,
                         tiers=("h100", "l4"),
                         provision_delay_by_tier={"l4": 0.03}))
    try:
        res = BenchmarkRunner(cluster, reqs, transport=cluster.transport,
                              autoscaler=asc).run(timeout=120)
    finally:
        cluster.shutdown()
    added = [t for _, t in asc.scaleups]
    assert added and all(t == "l4" for t in added), \
        f"expected cheap-tier scale-ups, got {added}"
    assert cluster.replica_tiers[0] == "h100"
    assert all(t == "l4" for t in cluster.replica_tiers[1:])
    assert all(e.cfg.chip == "l4" for e in cluster.engines[1:])
    specs = tier_specs()
    assert cluster.router.weights[1] == specs["l4"].throughput_factor
    assert cluster.router.costs[1] == specs["l4"].cost_per_replica_s
    assert len(cluster.finished) == 40
    assert res.cost_dollars > 0
    assert res.tier_seconds.get("l4", 0) > 0


# =========================================================================
# mixed-pool emulator-vs-DES parity
# =========================================================================

def test_hetero_emulator_matches_des_static_pool():
    """Fixed h100+l4 pool, no autoscaler: per-request latencies agree within
    one slow-tier step — heterogeneous step times alone open no gap."""
    reqs = workload(n=16, qps=30.0)
    reqs_des = copy.deepcopy(reqs)
    ecfg = engine_cfg(enable_prefix_caching=False)
    cluster = build(["h100", "l4"], ecfg=ecfg)
    try:
        BenchmarkRunner(cluster, reqs,
                        transport=cluster.transport).run(timeout=120)
        emu = {r.request_id: r.e2e_latency() for r in cluster.finished}
    finally:
        cluster.shutdown()

    des = DiscreteEventSimulator(
        StaticPredictor(DT["h100"]),
        DESConfig(max_num_seqs=8, max_batched_tokens=64, step_overhead_s=0.0),
        num_replicas=2, router=make_router("round_robin", 2),
        replica_tiers=["h100", "l4"], tier_predictors=tier_predictors(),
        tier_specs=tier_specs(ecfg))
    sims = des.run(reqs_des)
    slow = max(DT.values())
    for orig, sim in zip(reqs_des, sims):
        assert sim.finish_time is not None
        err = abs(emu[orig.request_id] - (sim.finish_time - sim.arrival_time))
        assert err <= slow + 1e-9, \
            f"request {orig.request_id} diverges by {err / slow:.2f} steps"


def test_hetero_elastic_emulator_matches_des():
    """Mixed pool + scripted tier-selecting scale-up mid-run: both sides add
    the same (cheapest) tier at the same virtual time and latencies agree
    within one slow-tier step.  One ``repro.scenario.compare`` call
    replaces the hand-rolled emulator+DES plumbing: the scenario spec
    carries the pool, the schedule, and the per-tier predictors, and both
    backends are wired from it identically by construction."""
    from repro.scenario import compare, scenario_with, get_preset

    scenario = scenario_with(
        get_preset("elastic_tier_parity"),
        name="hetero_elastic_parity",
        **{"workload.arrival": "poisson",   # queued regime, same bar
           "workload.qps": 30.0,
           "workload.num_requests": 16,
           "workload.output_len_mean": 8.0,
           "workload.max_output_len": 12,
           "pool.tier_step_time_s": DT,
           "autoscale.schedule": [[0.08, 1]],
           "autoscale.interval_s": 0.05,
           "seed": 3})
    cres = compare(scenario, backends=("thread", "des"), timeout=120)
    emu, des = cres.results["thread"], cres.results["des"]
    assert emu.tiers_added == des.tiers_added == ["l4"]
    assert emu.replica_tiers == des.replica_tiers == ["h100", "l4", "l4"]
    assert cres.decisions_equal
    assert cres.max_err_steps <= 1.0
    assert emu.num_requests == des.num_requests == 16


def test_des_rejects_unknown_tier():
    with pytest.raises(ValueError):
        DiscreteEventSimulator(StaticPredictor(5e-3), num_replicas=1,
                               replica_tiers=["l4"])


# =========================================================================
# tier-aware drain victim selection (shared emulator/DES rule)
# =========================================================================

def test_drain_victim_prefers_expensive_idle_tier():
    costs = {0: 5.5 / 3600, 1: 0.8 / 3600, 2: 5.5 / 3600}
    # all idle: the pricier tier goes first, index breaks the h100 tie
    assert drain_victim([0, 1, 2], idle_of=lambda i: True,
                        cost_of=costs.get) == 2
    assert drain_victim([0, 1], idle_of=lambda i: True,
                        cost_of=costs.get) == 0
    # only the cheap replica is idle: it wins over busy expensive ones
    assert drain_victim([0, 1, 2], idle_of=lambda i: i == 1,
                        cost_of=costs.get) == 1
    # nobody idle: same (cost, index) order over the busy set
    assert drain_victim([0, 1, 2], idle_of=lambda i: False,
                        cost_of=costs.get) == 2
    # untiered pool (cost 0.0 everywhere): historical highest-index rule
    assert drain_victim([0, 1, 2], idle_of=lambda i: True,
                        cost_of=lambda i: 0.0) == 2
    assert drain_victim([0], idle_of=lambda i: True,
                        cost_of=lambda i: 0.0) is None


def test_autoscaler_drains_expensive_idle_tier_first():
    """Mixed quiet pool [h100, l4, h100]: the scripted scale-down must give
    back an idle h100 (highest index breaks the tie), not the historical
    highest-index-only victim semantics' cheapest... i.e. never the l4."""
    reqs = workload(n=8, qps=1e4)
    tail = workload(n=1, qps=1.0, seed=9)
    tail[0].arrival_time = 1.0        # keeps the run alive past the drain
    cluster = build(["h100", "l4", "h100"])
    asc = Autoscaler(cluster, SchedulePolicy([(0.4, -1)]),
                     AutoscalerConfig(interval_s=0.05, provision_delay_s=0.1,
                                      min_replicas=1, max_replicas=3))
    try:
        BenchmarkRunner(cluster, reqs + tail, transport=cluster.transport,
                        autoscaler=asc).run(timeout=120)
        drained = [m["replica"] for m in cluster.membership_events()
                   if m["drained"] is not None]
        assert drained == [2], \
            f"expected the idle h100 at index 2 to drain, got {drained}"
        assert len(cluster.finished) == 9
    finally:
        cluster.shutdown()


def test_hetero_drain_parity_emulator_vs_des():
    """Scripted drain on a mixed [h100, l4, h100] pool: the shared
    drain_victim rule must retire the same replica at the same virtual time
    on both sides, keeping per-request latencies within one slow step."""
    events = [(0.4, -1)]
    asc_cfg = AutoscalerConfig(interval_s=0.05, provision_delay_s=0.1,
                               min_replicas=1, max_replicas=3)
    reqs = workload(n=12, qps=40.0)
    reqs[-1].arrival_time = 1.0
    reqs_des = copy.deepcopy(reqs)
    ecfg = engine_cfg(enable_prefix_caching=False)

    cluster = build(["h100", "l4", "h100"], ecfg=ecfg)
    asc = Autoscaler(cluster, SchedulePolicy(events), asc_cfg)
    try:
        BenchmarkRunner(cluster, reqs, transport=cluster.transport,
                        autoscaler=asc).run(timeout=120)
        emu = {r.request_id: r.e2e_latency() for r in cluster.finished}
        emu_drained = [m["replica"] for m in cluster.membership_events()
                       if m["drained"] is not None]
    finally:
        cluster.shutdown()

    des = DiscreteEventSimulator(
        StaticPredictor(DT["h100"]),
        DESConfig(max_num_seqs=8, max_batched_tokens=64, step_overhead_s=0.0),
        num_replicas=3, router=make_router("round_robin", 3),
        autoscaler_policy=SchedulePolicy(events), autoscaler_cfg=asc_cfg,
        replica_tiers=["h100", "l4", "h100"],
        tier_predictors=tier_predictors(), tier_specs=tier_specs(ecfg))
    sims = des.run(reqs_des)

    des_drained = [r.index for r in des.replicas if r.drained_at is not None]
    assert emu_drained == des_drained == [2]
    slow = max(DT.values())
    for orig, sim in zip(reqs_des, sims):
        assert sim.finish_time is not None
        err = abs(emu[orig.request_id] - (sim.finish_time - sim.arrival_time))
        assert err <= slow + 1e-9, \
            f"request {orig.request_id} diverges by {err / slow:.2f} steps"
