"""Batched clock coordination: run merging, park coalescing, write
combining, and the parallel sweep/compare fan-out.

The fast path must be *invisible* semantically: a run of consecutive jump
targets submitted in one request resolves to exactly the trajectory the
legacy one-target-per-request protocol produced (minimum-target rule per
merged step, no actor ever jumped past a target it has not requested).
These tests pin that equivalence at three levels — Timekeeper unit tests,
a property test over random run shapes, and same-seed end-to-end scenario
runs with ``REPRO_CLOCK_BATCHING`` toggled on both cluster backends.
"""

from __future__ import annotations

import json
import socket
import struct
import threading

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # optional dev dependency
    from _hypothesis_compat import given, settings, st

from repro.core.client import (LocalTransport, TimeJumpClient,
                               batching_enabled)
from repro.core.clock import ManualWallSource, VirtualClock
from repro.core.timekeeper import Timekeeper
from repro.core.transport import FrameWriter, pack_frame
from repro.scenario import (compare, derive_cell_seed, get_preset, run,
                            run_sweep, scenario_with, Sweep)


def _manual_tk() -> Timekeeper:
    return Timekeeper(clock=VirtualClock(ManualWallSource()),
                      jitter_cooldown=0.0)


# =========================================================================
# Timekeeper: merged rounds
# =========================================================================

def test_jump_run_merges_aligned_rounds():
    tk = _manual_tk()
    for a in ("a", "b"):
        tk.register_actor(a)
    targets = [0.001 * (j + 1) for j in range(10)]
    tk.request_jump_run("a", targets)
    assert tk.clock.now() == 0.0          # b has no queue yet: no advance
    tk.request_jump_run("b", targets)
    assert tk.clock.now() == pytest.approx(0.010)
    assert tk.stats.rounds == 10          # one logical round per merged step
    assert tk.stats.merged_rounds == 9    # resolved in a single burst
    assert tk.stats.batched_requests == 2
    assert tk.stats.requests == 2
    d = tk.stats.as_dict()
    for k in ("batched_requests", "merged_rounds", "coalesced_parks"):
        assert k in d
    tk.close()


def test_burst_stops_at_short_run():
    """A burst cannot advance past the end of the shortest queue — the
    no-rollback causality rule: once 'a' has consumed its only target, the
    barrier stalls until 'a' asks for more, leaving 'b' parked mid-run."""
    tk = _manual_tk()
    for a in ("a", "b"):
        tk.register_actor(a)
    tk.request_jump_run("a", [0.005])
    tk.request_jump_run("b", [0.002, 0.004, 0.006, 0.008])
    assert tk.clock.now() == pytest.approx(0.005)     # not 0.008
    tk.request_jump_run("a", [0.020])
    assert tk.clock.now() == pytest.approx(0.008)     # b's leftovers drain
    tk.close()


def test_request_jump_is_the_single_target_case():
    tk = _manual_tk()
    tk.register_actor("solo")
    tk.request_jump("solo", 0.5)
    assert tk.clock.now() == pytest.approx(0.5)
    assert tk.stats.batched_requests == 0    # singles are not "batched"
    assert tk.stats.requests == 1
    tk.close()


@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 5), st.integers(1, 5),
                          st.integers(1, 5)),
                min_size=1, max_size=6))
def test_merged_rounds_never_pass_any_actors_minimum(rounds):
    """Property: at every point, virtual time ≤ the smallest
    maximum-target-ever-submitted across actors — i.e. no merged burst ever
    advances the clock past a target some actor has not yet requested."""
    tk = _manual_tk()
    actors = ("a", "b", "c")
    for a in actors:
        tk.register_actor(a)
    max_submitted = {a: 0.0 for a in actors}
    try:
        for lens in rounds:
            for a, k in zip(actors, lens):
                base = tk.clock.now()
                targets = [base + 0.001 * (j + 1) for j in range(k)]
                max_submitted[a] = max(max_submitted[a], targets[-1])
                tk.request_jump_run(a, targets)
                assert tk.clock.now() <= min(max_submitted.values()) + 1e-9
    finally:
        tk.close()


# =========================================================================
# Timekeeper: park/unpark coalescing
# =========================================================================

def test_park_after_coalesces_into_the_barrier():
    tk = _manual_tk()
    for a in ("a", "b"):
        tk.register_actor(a)
    tk.request_jump_run("a", [0.002, 0.004], park_after=True)
    tk.request_jump_run("b", [0.010])
    # burst: a consumes both targets, parks in the same resolution, and b
    # then advances alone to 0.010 — no separate park RPC round trip.
    assert tk.clock.now() == pytest.approx(0.010)
    assert tk.num_parked == 1
    assert tk.stats.parks == 1
    assert tk.stats.coalesced_parks == 1
    # unpark folded into the next run request
    tk.park_actor("b")
    tk.request_jump_run("a", [0.020], unpark=True)
    assert tk.clock.now() == pytest.approx(0.020)
    assert tk.stats.unparks == 1
    assert tk.stats.coalesced_parks == 2
    tk.close()


def test_client_jump_run_with_park_after():
    tk = _manual_tk()
    tr = LocalTransport(tk)
    a = TimeJumpClient(tr, "a", batched=True)
    b = TimeJumpClient(tr, "b", batched=True)

    t = threading.Thread(
        target=lambda: a.jump_run([0.002, 0.004], park_after=True))
    t.start()
    b.jump_run([0.010])
    t.join(timeout=30)
    assert not t.is_alive()
    assert tk.clock.now() == pytest.approx(0.010)
    assert tk.num_parked == 1
    assert tk.stats.coalesced_parks == 1

    b.park()
    a.jump_run([0.020])          # implicit unpark folded into the request
    assert tk.clock.now() == pytest.approx(0.020)
    assert tk.stats.coalesced_parks == 2
    a.deregister()
    b.unpark()
    b.deregister()
    tk.close()


def test_batched_client_trajectory_matches_unbatched():
    """Two same-shape schedules, one driven through jump_run chunks and one
    through single time_jump calls, land on identical virtual timestamps."""
    final = {}
    for batched in (False, True):
        tk = _manual_tk()
        tr = LocalTransport(tk)
        clients = [TimeJumpClient(tr, f"w{i}", batched=batched)
                   for i in range(3)]

        def drive(c):
            if batched:
                for _ in range(4):
                    t0 = c.now()
                    c.jump_run([t0 + 0.001 * (j + 1) for j in range(5)])
            else:
                for _ in range(20):
                    c.time_jump(0.001)
            c.deregister()

        threads = [threading.Thread(target=drive, args=(c,))
                   for c in clients]
        for th in threads:
            th.start()
        for th in threads:
            th.join(timeout=60)
            assert not th.is_alive()
        final[batched] = tk.clock.now()
        tk.close()
    assert final[True] == pytest.approx(final[False])
    assert final[True] == pytest.approx(0.020)


def test_batching_enabled_env_parsing(monkeypatch):
    monkeypatch.delenv("REPRO_CLOCK_BATCHING", raising=False)
    assert batching_enabled() is True
    for off in ("0", "off", "FALSE", "no"):
        monkeypatch.setenv("REPRO_CLOCK_BATCHING", off)
        assert batching_enabled() is False
    monkeypatch.setenv("REPRO_CLOCK_BATCHING", "1")
    assert batching_enabled() is True


# =========================================================================
# FrameWriter: the socket write combiner
# =========================================================================

def _recv_frames(sock, n):
    frames, buf = [], b""
    while len(frames) < n:
        chunk = sock.recv(65536)
        assert chunk, "peer closed early"
        buf += chunk
        while len(buf) >= 4:
            ln = struct.unpack(">I", buf[:4])[0]
            if len(buf) < 4 + ln:
                break
            frames.append(buf[4:4 + ln])
            buf = buf[4 + ln:]
    assert not buf
    return frames


def test_frame_writer_preserves_frames_and_batches():
    a, b = socket.socketpair()
    try:
        w = FrameWriter(a)
        payloads = [f"frame-{i}".encode() for i in range(64)]
        # one multi-frame send: must coalesce into few flushes
        w.send(*[pack_frame(p) for p in payloads[:32]])
        # concurrent senders: every frame still arrives intact, in order
        # within each sender
        def sender(lo, hi):
            for p in payloads[lo:hi]:
                w.send(pack_frame(p))
        threads = [threading.Thread(target=sender, args=(32 + 16 * i,
                                                         48 + 16 * i))
                   for i in range(2)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=30)
        got = _recv_frames(b, len(payloads))
        assert sorted(got) == sorted(payloads)
        assert w.frames == len(payloads)
        assert w.flushes <= w.frames          # combining never inflates
        assert w.flushes >= 1
    finally:
        a.close()
        b.close()


# =========================================================================
# End-to-end: same seed, batching on vs off
# =========================================================================

def _small_parity_scenario(replicas=2, n=8):
    return scenario_with(get_preset("distributed_parity"),
                         name="batch_toggle",
                         **{"pool.replicas": replicas,
                            "workload.num_requests": n})


def test_thread_backend_byte_identical_batching_toggle(monkeypatch):
    """Thread backend is deterministic under ManualWallSource: the batched
    fast path must reproduce the legacy trajectory *exactly* — same routing
    decisions, bit-equal per-request latencies."""
    scenario = _small_parity_scenario()
    results = {}
    for flag in ("0", "1"):
        monkeypatch.setenv("REPRO_CLOCK_BATCHING", flag)
        results[flag] = run(scenario, backend="thread", timeout=120)
    a, b = results["0"], results["1"]
    assert a.routing_decisions == b.routing_decisions
    assert a.latencies == b.latencies          # bit-equal, not approx
    assert a.makespan_virtual == b.makespan_virtual


def test_process_backend_parity_batching_toggle(monkeypatch):
    """Process backend carries wall-rate absorption (Eq. 1), so the bar is
    the repo's distributed parity bar: identical decisions, per-request
    TTFT/TPOT within one slow step across the batching toggle."""
    scenario = _small_parity_scenario()
    step = scenario.pool.step_time_s
    results = {}
    for flag in ("0", "1"):
        monkeypatch.setenv("REPRO_CLOCK_BATCHING", flag)
        results[flag] = run(scenario, backend="process", timeout=300)
    a, b = results["0"], results["1"]
    assert a.routing_decisions == b.routing_decisions
    assert set(a.latencies) == set(b.latencies)
    for k, (ttft_a, tpot_a, _) in a.latencies.items():
        ttft_b, tpot_b, _ = b.latencies[k]
        assert abs(ttft_a - ttft_b) <= step
        assert abs(tpot_a - tpot_b) <= step


# =========================================================================
# Parallel sweeps and compare --jobs
# =========================================================================

def test_derive_cell_seed_is_stable_and_name_sensitive():
    assert derive_cell_seed(7, "cell[a=1]") == derive_cell_seed(7, "cell[a=1]")
    assert derive_cell_seed(7, "cell[a=1]") != derive_cell_seed(7, "cell[a=2]")
    assert derive_cell_seed(7, "x") != derive_cell_seed(8, "x")
    s = derive_cell_seed(2**40, "big")
    assert 0 <= s < 2**31 - 1


def test_run_sweep_parallel_matches_serial():
    sweep = Sweep(_small_parity_scenario(n=6),
                  {"workload.qps": [2.0, 4.0], "pool.replicas": [1, 2]})
    serial = run_sweep(sweep, backend="des", jobs=1)
    fanned = run_sweep(sweep, backend="des", jobs=2)
    assert len(serial) == len(fanned) == 4
    # ordered, deterministic, jobs-invariant
    assert [r.scenario for r in serial] == [r.scenario for r in fanned]
    wall_keys = {"wall_s", "speedup_x"}    # wall-clock noise, not semantics
    for a, b in zip(serial, fanned):
        ra = {k: v for k, v in a.to_row().items() if k not in wall_keys}
        rb = {k: v for k, v in b.to_row().items() if k not in wall_keys}
        assert ra == rb
        assert a.routing_decisions == b.routing_decisions
        assert a.latencies == b.latencies


def test_run_sweep_derive_seeds():
    sweep = Sweep(_small_parity_scenario(n=6), {"workload.qps": [2.0, 4.0]})
    res = run_sweep(sweep, backend="des", jobs=1, derive_seeds=True)
    seeds = [r.seed for r in res]
    assert seeds[0] != seeds[1]          # per-cell, name-derived
    again = run_sweep(sweep, backend="des", jobs=2, derive_seeds=True)
    assert [r.seed for r in again] == seeds


def test_compare_all_backends_with_parallel_jobs():
    """The regression gate from the issue: compare() across all three
    backends with jobs > 1 must still clear the parity bar."""
    scenario = _small_parity_scenario()
    cres = compare(scenario, backends=("thread", "process", "des"),
                   timeout=300, jobs=2)
    assert cres.decisions_equal
    assert cres.max_err_steps <= 1.0


def test_scenario_result_carries_timekeeper_stats():
    res = run(_small_parity_scenario(n=4), backend="thread", timeout=120)
    assert res.num_steps > 0
    assert isinstance(res.timekeeper, dict)
    for k in ("rounds", "requests", "batched_requests", "merged_rounds",
              "coalesced_parks"):
        assert k in res.timekeeper
    # artifact plumbing: counters survive JSON round-trips for bench rows
    json.dumps(res.timekeeper)
