"""Shared-memory transport: rings, seqlock clock word, and the shm
Timekeeper plane (paper §5, zero-syscall variant).

Covers the SPSC ring contract (framing, wrap, EOF drain ordering, oversize
rejection, dead-peer drain-then-None), seqlock torn-read safety under a
concurrent writer, the ActorTransport surface over rings (jump roundtrip,
coordination, park, server close), the epoch-broadcast collapse on BOTH
transports (tagged FrameWriter coalescing on TCP; single-word publish by
construction on shm), and segment reclaim.

Everything here runs in-process: "child" views attach to the same segment
from threads, which exercises identical byte-level code paths to a spawned
process without the spawn overhead.  Cross-process behaviour (SIGKILL
recovery, handshake, ledger exactness) is covered by the process-backend
suite and the chaos scenario presets.
"""

import socket
import struct
import threading
import time

import pytest

from repro.core.client import TimeJumpClient, TransportClosed
from repro.core.shm_transport import (ShmClockWord, ShmEndpoint,
                                      ShmReplicaClock, ShmTimekeeperServer)
from repro.core.transport import FrameWriter

pytestmark = pytest.mark.timeout(120)


@pytest.fixture()
def endpoint_pair():
    """A server + one endpoint with its service thread running."""
    srv = ShmTimekeeperServer(jitter_cooldown=0.0)
    ep = ShmEndpoint.create(srv.clock_word.name)
    srv.serve(ep.tk_c2p, ep.tk_p2c, name="shm-tk-test")
    yield srv, ep
    srv.close()
    ep.unlink()


# =========================================================================
# SPSC ring
# =========================================================================

def test_ring_roundtrip_and_wrap():
    """Frames survive byte-exact across many sends on a ring small enough
    that payloads wrap the buffer repeatedly."""
    srv = ShmTimekeeperServer(jitter_cooldown=0.0)
    ep = ShmEndpoint.create(srv.clock_word.name, tk_cap=256, ctrl_cap=256)
    try:
        ring = ep.tk_c2p
        for i in range(64):                     # 64 frames through 256 bytes
            payload = bytes([i]) * (40 + i % 50)
            ring.send_bytes(payload)
            assert ring.recv_bytes(timeout=1.0) == payload
        assert ring.frames_in == ring.frames_out == 64
    finally:
        srv.close()
        ep.unlink()


def test_ring_eof_drains_queued_frames_first():
    """EOF is a graceful close: frames committed before it must still be
    delivered (ledger exactness), then None."""
    srv = ShmTimekeeperServer(jitter_cooldown=0.0)
    ep = ShmEndpoint.create(srv.clock_word.name)
    try:
        ring = ep.ctrl_p2c
        ring.send_bytes(b"first")
        ring.send_bytes(b"second")
        ring.set_eof()
        assert ring.recv_bytes(timeout=1.0) == b"first"
        assert ring.recv_bytes(timeout=1.0) == b"second"
        assert ring.recv_bytes(timeout=1.0) is None
        with pytest.raises(TransportClosed):
            ring.send_bytes(b"after-eof")
    finally:
        srv.close()
        ep.unlink()


def test_ring_rejects_oversize_frame():
    """A frame that can never fit must fail loudly, not deadlock waiting
    for space that will never exist."""
    srv = ShmTimekeeperServer(jitter_cooldown=0.0)
    ep = ShmEndpoint.create(srv.clock_word.name, tk_cap=128, ctrl_cap=128)
    try:
        with pytest.raises(ValueError):
            ep.tk_c2p.send_bytes(b"x" * 130)
    finally:
        srv.close()
        ep.unlink()


def test_ring_dead_peer_drains_then_eof():
    """A SIGKILLed peer can never set eof: with peer_alive=False the reader
    must drain whatever was committed, then surface None — not hang."""
    srv = ShmTimekeeperServer(jitter_cooldown=0.0)
    ep = ShmEndpoint.create(srv.clock_word.name)
    try:
        ring = ep.ctrl_c2p
        ring.send_bytes(b"committed-before-death")
        dead = lambda: False
        assert ring.recv_bytes(timeout=5.0, peer_alive=dead) == \
            b"committed-before-death"
        t0 = time.monotonic()
        assert ring.recv_bytes(timeout=5.0, peer_alive=dead) is None
        assert time.monotonic() - t0 < 2.0, "dead-peer EOF took too long"
    finally:
        srv.close()
        ep.unlink()


def test_doorbell_wakes_blocked_consumer_and_survives_peer_close():
    """The wake-socket path end to end: with the doorbell handshake done, a
    consumer blocked in select wakes on a producer's send, and closing the
    peer's sockets (what a SIGKILL does to fds) degrades the reader to the
    bounded-poll fallback — drain, then None — instead of wedging."""
    srv = ShmTimekeeperServer(jitter_cooldown=0.0)
    parent = ShmEndpoint.create(srv.clock_word.name)
    child = ShmEndpoint.attach(parent.spec)
    try:
        assert parent.accept_wakes(2.0), "doorbell handshake failed"
        assert child.ctrl_p2c.wake is not None
        got = {}

        def reader():
            got["frame"] = child.ctrl_p2c.recv_bytes(timeout=5.0)

        t = threading.Thread(target=reader)
        t.start()
        time.sleep(0.05)                   # reader is asleep in select
        parent.ctrl_p2c.send_bytes(b"ding")
        t.join(timeout=2.0)
        assert got.get("frame") == b"ding"
        parent.close_wakes()               # fd-close == peer crash
        dead = lambda: False
        t0 = time.monotonic()
        assert child.ctrl_p2c.recv_bytes(timeout=5.0,
                                         peer_alive=dead) is None
        assert time.monotonic() - t0 < 2.0, "post-crash recv took too long"
    finally:
        srv.close()
        child.close_wakes()
        parent.unlink()


def test_broadcast_kick_respects_wake_target():
    """Epoch broadcasts must wake only sleepers whose advertised virtual
    wake target the round reached — the no-thundering-herd contract."""
    from repro.core.shm_transport import _WakeSock
    srv = ShmTimekeeperServer(jitter_cooldown=0.0)
    ep = ShmEndpoint.create(srv.clock_word.name)
    a, b = socket.socketpair()
    try:
        ring = ep.tk_p2c
        ring.wake = _WakeSock(a)
        b.setblocking(False)
        ring.advertise(True, 100.0)        # sleeper rides to t=100
        ring.kick_if_due(50.0)             # round at t=50: not its turn
        with pytest.raises(BlockingIOError):
            b.recv(1)
        ring.kick_if_due(100.0)            # its round arrives
        b.settimeout(1.0)
        assert b.recv(1) == b"\0"
        ring.advertise(True)               # no target: any event wakes
        ring.kick_if_due(-1e18)
        assert b.recv(1) == b"\0"
    finally:
        a.close()
        b.close()
        srv.close()
        ep.unlink()


def test_ring_timeout_raises():
    srv = ShmTimekeeperServer(jitter_cooldown=0.0)
    ep = ShmEndpoint.create(srv.clock_word.name)
    try:
        with pytest.raises(TransportClosed):
            ep.ctrl_p2c.recv_bytes(timeout=0.05)
    finally:
        srv.close()
        ep.unlink()


# =========================================================================
# seqlock clock word
# =========================================================================

def test_clock_word_never_tears_under_concurrent_writes():
    """Writer publishes (offset, epoch) pairs with offset == epoch * 1e-3;
    readers must never observe a pair violating that invariant."""
    word = ShmClockWord.create()
    try:
        stop = threading.Event()
        torn = []

        def reader():
            rd = ShmClockWord.attach(word.name)
            try:
                while not stop.is_set():
                    offset, epoch, _ = rd.read()
                    if abs(offset - epoch * 1e-3) > 1e-12:
                        torn.append((offset, epoch))
                        return
            finally:
                rd.close()

        threads = [threading.Thread(target=reader) for _ in range(2)]
        for t in threads:
            t.start()
        for epoch in range(1, 20001):
            word.publish(epoch * 1e-3, epoch)
        stop.set()
        for t in threads:
            t.join(10)
        assert not torn, f"torn seqlock reads: {torn[:3]}"
        assert word.read()[:2] == (20000 * 1e-3, 20000)
    finally:
        word.unlink()
        word.close()


def test_replica_clock_tracks_word_and_closed_flag():
    word = ShmClockWord.create()
    try:
        clk = ShmReplicaClock(word)
        word.publish(3.5, 7)
        assert clk.offset == 3.5
        assert clk.epoch == 7
        assert not clk.closed
        assert abs(clk.now() - (time.time() + 3.5)) < 0.25
        # wait_for_update: returns once the epoch moves...
        def bump():
            time.sleep(0.05)
            word.publish(3.6, 8)
        t = threading.Thread(target=bump)
        t.start()
        assert clk.wait_for_update(7, timeout=5.0)
        t.join()
        # ...times out when it does not...
        assert not clk.wait_for_update(8, timeout=0.05)
        # ...and a closed word releases waiters immediately.
        word.publish(3.6, 8, closed=True)
        assert clk.wait_for_update(8, timeout=5.0)
        assert clk.closed
    finally:
        word.unlink()
        word.close()


# =========================================================================
# timekeeper plane over rings
# =========================================================================

def test_shm_jump_roundtrip(endpoint_pair):
    _, ep = endpoint_pair
    tr = ep.child_transport()
    c = TimeJumpClient(tr, "shm-a")
    t0 = c.now()
    t1 = c.time_jump(0.2)
    assert t1 >= t0 + 0.2 - 1e-6
    c.deregister()
    tr.close()


def test_two_shm_clients_coordinate():
    srv = ShmTimekeeperServer(jitter_cooldown=0.0)
    eps = []
    for i in range(2):
        ep = ShmEndpoint.create(srv.clock_word.name)
        srv.serve(ep.tk_c2p, ep.tk_p2c, name=f"shm-tk-{i}")
        eps.append(ep)
    try:
        tra = eps[0].child_transport()
        trb = eps[1].child_transport()
        a = TimeJumpClient(tra, "A")
        b = TimeJumpClient(trb, "B")
        results = {}

        def run(name, client, dt, n):
            t0 = time.monotonic()
            for _ in range(n):
                client.time_jump(dt)
            results[name] = time.monotonic() - t0

        ta = threading.Thread(target=run, args=("A", a, 0.05, 10))
        tb = threading.Thread(target=run, args=("B", b, 0.025, 20))
        ta.start(); tb.start(); ta.join(); tb.join()
        assert max(results.values()) < 0.4, results
        # both replica clocks read the SAME word: agreement is exact
        assert tra.clock.epoch == trb.clock.epoch
        a.deregister(); b.deregister()
        tra.close(); trb.close()
    finally:
        srv.close()
        for ep in eps:
            ep.unlink()


def test_shm_server_close_releases_waiters(endpoint_pair):
    srv, ep = endpoint_pair
    tr = ep.child_transport()
    c = TimeJumpClient(tr, "waiter")
    released = threading.Event()

    def jump():
        try:
            c.time_jump(30.0)       # 30 wall seconds if it degraded
        except (TransportClosed, KeyError):
            pass
        released.set()

    t = threading.Thread(target=jump)
    t.start()
    time.sleep(0.05)
    srv.close()
    t.join(timeout=5.0)
    assert released.is_set(), \
        "waiter rode out its degradation timeout after server close"
    assert tr.closed
    tr.close()


def test_shm_ring_eof_deregisters_actors(endpoint_pair):
    """Transport close == connection death: the service loop must
    deregister the peer's actors so the barrier is never wedged."""
    srv, ep = endpoint_pair
    tr = ep.child_transport()
    c = TimeJumpClient(tr, "doomed")
    assert srv.timekeeper.num_actors == 1
    tr.close()
    deadline = time.monotonic() + 5.0
    while srv.timekeeper.num_actors and time.monotonic() < deadline:
        time.sleep(0.01)
    assert srv.timekeeper.num_actors == 0


def test_segment_reclaim_after_unlink():
    srv = ShmTimekeeperServer(jitter_cooldown=0.0)
    ep = ShmEndpoint.create(srv.clock_word.name)
    seg, clock_name = ep.spec.segment, srv.clock_word.name
    ep.unlink()
    srv.close()
    from multiprocessing import shared_memory
    for name in (seg, clock_name):
        with pytest.raises(FileNotFoundError):
            s = shared_memory.SharedMemory(name=name)
            s.close()


# =========================================================================
# epoch-broadcast collapse: both transports (satellite regression)
# =========================================================================

def test_tcp_clock_broadcast_collapses_under_slow_socket():
    """A burst of N epoch bumps must leave at most ONE pending clock frame
    per peer: tagged frames replace their still-queued predecessor while
    the flusher is stuck inside a slow syscall."""
    a, b = socket.socketpair()
    try:
        w = FrameWriter(a)
        stuck = threading.Event()
        release = threading.Event()
        orig = w._write_batch

        def slow_batch(batch):
            stuck.set()
            assert release.wait(10)
            orig(batch)

        w._write_batch = slow_batch
        first = struct.pack("<Q", 0)
        t = threading.Thread(target=w.send, args=(first,),
                             kwargs={"tag": "clock"})
        t.start()
        assert stuck.wait(10)            # flusher wedged inside the syscall
        for epoch in range(1, 51):       # the burst arrives meanwhile
            w.send(struct.pack("<Q", epoch), tag="clock")
        assert w.pending() <= 1, "clock burst piled up behind a slow socket"
        assert w.coalesced >= 49
        release.set()
        t.join(10)
        # Only the first frame and the LAST of the burst ever hit the wire.
        b.settimeout(5.0)
        wire = b.recv(4096)
        assert wire == struct.pack("<Q", 0) + struct.pack("<Q", 50)
    finally:
        a.close()
        b.close()


def test_shm_epoch_burst_is_one_word_no_frames(endpoint_pair):
    """On shm the collapse is by construction: N bumps are N overwrites of
    one seqlock word — zero broadcast frames enter any ring, and readers
    see exactly the latest epoch."""
    srv, ep = endpoint_pair
    tr = ep.child_transport()
    c = TimeJumpClient(tr, "burster")
    replies_before = ep.tk_p2c.frames_out
    for _ in range(20):                  # 20 epoch bumps via real jumps
        c.time_jump(0.01)
    tk = srv.timekeeper
    assert tr.clock.epoch == tk.clock.epoch
    assert abs(tr.clock.offset - tk.clock.offset) < 1e-9
    # The reply ring carried NOTHING: jumps are one-way (the child pre-reads
    # its wait epoch from the word) and broadcasts are word overwrites — on
    # a fan-out or acked design either would show up as frames here.
    assert ep.tk_p2c.frames_out - replies_before == 0
    c.deregister()
    tr.close()
