"""Config-registry smoke: every module in ``src/repro/configs`` serves.

ROADMAP flags the per-architecture configs as dead weight: the model-level
suite (``test_models_smoke``) runs forward/train steps, but nothing proved
each config can actually *serve* — flow through ``build_cluster`` into a
scheduler and complete requests under the time-warp emulator.  This
parametrized smoke does exactly that per module: import, sanity-check the
CONFIG/reduced() surface, and drive a 1-replica tiny thread-backend
scenario to completion.
"""

import importlib

import pytest

from repro.cluster import build_cluster
from repro.configs import ARCH_IDS, PAPER_ARCH_IDS, get_reduced_config
from repro.core.predictor import StaticPredictor
from repro.serving.benchmark import BenchmarkRunner
from repro.serving.scheduler import EngineConfig
from repro.workload import WorkloadConfig, synthesize

pytestmark = pytest.mark.timeout(120)

ALL_IDS = ARCH_IDS + PAPER_ARCH_IDS


@pytest.mark.parametrize("arch", ALL_IDS)
def test_config_module_surface(arch):
    mod = importlib.import_module(f"repro.configs.{arch}")
    cfg = mod.CONFIG
    red = mod.reduced()
    # The reduced config must stay same-family but strictly smaller.
    assert red.d_model <= cfg.d_model
    assert red.num_layers <= cfg.num_layers
    assert cfg.vocab_size > 0 and red.vocab_size > 0


@pytest.mark.parametrize("arch", ALL_IDS)
def test_config_round_trips_into_fleet_pool(arch):
    """Every config id must survive the declarative path end to end:
    config id -> PoolSpec inside a FleetSpec -> strict JSON round trip ->
    engine construction (``build_cluster`` from the decoded pool)."""
    from repro.fleet import FleetSpec, ModelPoolSpec, TenantSpec
    from repro.scenario import PoolSpec, Scenario

    s = Scenario(
        name=f"fleet-{arch}",
        fleet=FleetSpec(
            models=(ModelPoolSpec(
                name="m",
                pool=PoolSpec(model=arch, reduced=True, replicas=1,
                              max_num_seqs=4, max_batched_tokens=64,
                              block_size=4, num_blocks=4096,
                              enable_prefix_caching=False,
                              step_time_s=5e-3)),),
            tenants=(TenantSpec(name="t", model="m"),)))
    assert Scenario.from_dict(s.to_dict()) == s
    mp = s.fleet.models[0]
    cluster = build_cluster(mp.pool.model_config(), mp.pool.engine_config(),
                            mp.pool.replicas, policy=mp.routing.policy,
                            predictor=StaticPredictor(5e-3),
                            backend="thread")
    try:
        assert len(cluster.replicas) == 1
    finally:
        cluster.shutdown()


@pytest.mark.parametrize("arch", ALL_IDS)
def test_config_serves_one_replica_scenario(arch):
    cfg = get_reduced_config(arch)
    engine = EngineConfig(policy="vllm", max_num_seqs=4,
                          max_batched_tokens=64, block_size=4,
                          num_blocks=4096, enable_prefix_caching=False)
    cluster = build_cluster(cfg, engine, 1, policy="round_robin",
                            predictor=StaticPredictor(5e-3),
                            backend="thread")
    try:
        reqs = synthesize(WorkloadConfig(
            num_requests=4, qps=16.0, prompt_len_mean=16, output_len_mean=4,
            max_prompt_len=32, max_output_len=8, seed=11))
        res = BenchmarkRunner(cluster, reqs,
                              transport=cluster.transport).run(timeout=60.0)
        assert res.num_requests == 4
        assert res.num_replicas == 1
        assert res.ttft.p50 > 0
    finally:
        cluster.shutdown()
