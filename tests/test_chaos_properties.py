"""Property tests for chaos fault injection: determinism + conservation.

Random fault schedules (crashes with either policy, with and without
recovery, stragglers) over a small deterministic scenario must satisfy two
invariants on every backend:

- **Determinism**: the same seed and schedule produce a byte-identical
  *semantic* result — fault log, requeue/fail outcomes, routing decisions,
  per-request latencies, billing.  (Wall-clock measurement fields —
  ``wall_seconds``, Timekeeper contention counters — are excluded: they
  measure the host, not the scenario.)
- **Conservation**: ``completed + failed == submitted`` — a fault may
  delay or fail a request but can never lose or duplicate one.

Fault times are drawn as continuous floats, so they land off the step and
arrival grids with probability one — the documented determinism contract
(a fault coinciding exactly with a step completion is ordered by event
sequence in the DES but by thread arrival in the emulator; see
``repro.cluster.faults``).

Uses the in-repo ``_hypothesis_compat`` shim when hypothesis isn't
installed: fixed pseudo-random examples, deterministic across runs.
"""

import dataclasses
import pickle

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # optional dev dependency
    from _hypothesis_compat import given, settings, st

from repro.cluster.faults import FaultSpec
from repro.scenario import get_preset, run, scenario_with


def _base():
    """3 untiered replicas, 10 uniformly spaced requests, no faults —
    the canvas every drawn schedule is painted onto."""
    s = scenario_with(get_preset("crash_recovery"),
                      **{"pool.replicas": 3})
    return dataclasses.replace(s, name="chaos_property", faults=())


def _faults_from(draws):
    faults = []
    for kind, t, replica, on_crash, recover in draws:
        if kind == "crash":
            faults.append(FaultSpec(
                kind="crash", time_s=t, replica=replica, on_crash=on_crash,
                recover=recover, respawn_delay_s=0.25))
        else:
            faults.append(FaultSpec(
                kind="straggler", time_s=t, replica=replica,
                slowdown=2.5, duration_s=0.4))
    return tuple(faults)


fault_draw = st.tuples(
    st.sampled_from(["crash", "straggler"]),
    st.floats(min_value=0.2, max_value=2.0),    # off-grid w.p. 1
    st.integers(min_value=0, max_value=2),
    st.sampled_from(["requeue", "fail"]),
    st.booleans())
schedules = st.lists(fault_draw, min_size=0, max_size=4)


def _semantic(res):
    """The scenario-determined projection of a ScenarioResult (everything
    except host-measurement fields)."""
    return (res.num_requests, res.requests_requeued, res.requests_failed,
            tuple(res.faults_injected), tuple(res.recovery_times),
            tuple(res.routing_decisions), tuple(res.scaleups),
            tuple(res.drained), res.makespan_virtual,
            res.replica_seconds, res.cost_dollars,
            tuple(sorted(res.latencies.items())),
            tuple(res.slo_samples))


@settings(max_examples=8, deadline=None)
@given(schedules)
def test_same_seed_is_byte_identical_and_conserving(draws):
    scenario = dataclasses.replace(_base(), faults=_faults_from(draws))
    n = scenario.workload.num_requests
    a = run(scenario, backend="thread", timeout=120)
    b = run(scenario, backend="thread", timeout=120)
    assert pickle.dumps(_semantic(a)) == pickle.dumps(_semantic(b)), \
        "same seed + same fault schedule must replay byte-identically"
    d = run(scenario, backend="des", timeout=120)
    for res in (a, b, d):
        assert res.num_requests + res.requests_failed == n, (
            f"{res.backend}: {res.num_requests} completed + "
            f"{res.requests_failed} failed != {n} submitted")
        # a fail-policy casualty is final: never also completed
        assert len(res.latencies) == res.num_requests


@settings(max_examples=6, deadline=None)
@given(schedules)
def test_des_replay_is_byte_identical(draws):
    """The DES leg of the same property: two simulator runs of one random
    schedule agree exactly (heap ordering is seeded, never wall-coupled)."""
    scenario = dataclasses.replace(_base(), faults=_faults_from(draws))
    a = run(scenario, backend="des", timeout=120)
    b = run(scenario, backend="des", timeout=120)
    assert pickle.dumps(_semantic(a)) == pickle.dumps(_semantic(b))
