"""End-to-end system tests: the paper's headline claims at test scale.

* fidelity — emulate-mode latency distributions match sleep-mode (ground
  truth by construction: same predictor, wall-clock sleeps) within 5%,
* acceleration — emulated virtual makespan ≫ wall time,
* the vLLM/SGLang policy split shows up in TPOT exactly as §6.2 describes,
* PD disaggregation works on top of the unmodified engine (Table 1),
* the DES baseline diverges when its feature model is stale (§2.3).
"""

import pytest

from repro.configs import get_reduced_config
from repro.core.predictor import StaticPredictor
from repro.serving.benchmark import BenchmarkRunner, compare_distributions
from repro.serving.scheduler import EngineConfig
from repro.serving.stack import build_stack
from repro.workload import WorkloadConfig, synthesize

MODEL = get_reduced_config("qwen2_5_3b")


def engine_cfg(**kw):
    base = dict(policy="vllm", max_num_seqs=16, max_batched_tokens=128,
                block_size=4, num_blocks=4096)
    base.update(kw)
    return EngineConfig(**base)


def workload(n=40, qps=20.0, seed=7, **kw):
    base = dict(num_requests=n, qps=qps, prompt_len_mean=48,
                output_len_mean=12, max_prompt_len=128, max_output_len=32,
                seed=seed)
    base.update(kw)
    return synthesize(WorkloadConfig(**base))


def run_mode(mode, reqs, *, policy="vllm", batch_s=4e-3, wall=None, **cfg_kw):
    stack = build_stack(MODEL, engine_cfg(policy=policy, **cfg_kw), mode,
                        predictor=StaticPredictor(batch_s),
                        use_worker_group=False, wall=wall)
    try:
        runner = BenchmarkRunner(stack.engine, reqs,
                                 transport=stack.transport)
        return runner.run(timeout=120)
    finally:
        stack.shutdown()


# =========================================================================
# fidelity: emulate vs sleep (paper Figs. 6/8)
# =========================================================================

def test_emulate_matches_sleep_distributions():
    """<5% median error at the paper's operating point (Fig. 8 mid-range,
    where control-plane overhead is a few % of step time — with 3 ms
    batches our pure-Python scheduler overhead dominates in a way vLLM's
    does not; benchmarks/fig8 sweeps this dependence explicitly).

    Hardened with the ManualWallSource treatment (same as
    test_two_actor_min_advancement): the *emulate* run uses a manual wall,
    so its timeline is pure jump arithmetic — exactly reproducible, no
    wall-rate CPU absorption, no OS jitter on that side of the comparison.
    The sleep baseline must keep a real wall (its correctness comes from
    genuinely concurrent wall-clock waiting; serialising a manual wall
    across sleeper threads would distort the timeline), so residual noise is
    sleep-side only and the gates + one retry absorb it.

    Operating point chosen for CI robustness: 40 ms batches and n=48 keep
    the wall-clock baseline's OS sleep jitter (~1-2 ms per step) small
    relative to the measured latencies; 20 ms batches with n=24 flake
    (the jitter is ~8% of a 26 ms median TTFT)."""
    from repro.core.clock import ManualWallSource

    # Deterministic side: compute once — identical on every attempt.
    res_emu = run_mode("emulate", workload(n=48, qps=6.0), batch_s=40e-3,
                       wall=ManualWallSource())
    # One retry for the sleep side: shared CI boxes show bursty multi-ms
    # noise that shifts an entire sleep-mode run; a *real* fidelity
    # regression is systematic and fails both attempts, while a noise burst
    # passes the re-measurement.
    for attempt in range(2):
        res_sleep = run_mode("sleep", workload(n=48, qps=6.0), batch_s=40e-3)

        ttft_err = compare_distributions(res_sleep.ttft, res_emu.ttft)
        tpot_err = compare_distributions(res_sleep.tpot, res_emu.tpot)
        # p95 rather than p99 for the tail: the p99 of 48 samples is a
        # single max-ish order statistic of wall jitter
        # Gates at 2x the paper's 5%: shared-CI wall jitter alone reaches
        # ~9% of these latencies for whole runs at a time, while any
        # structural fidelity bug (missed jump, double-counted step time)
        # shows up as tens of percent.  The strict <5% claim is verified
        # statistically in benchmarks/fig6 & fig8.
        if (ttft_err["median_rel_err"] < 0.10
                and tpot_err["median_rel_err"] < 0.10
                and ttft_err["p95_rel_err"] < 0.15):
            break
    else:
        raise AssertionError(
            f"fidelity off on both attempts: ttft={ttft_err} tpot={tpot_err}")


def test_emulation_accelerates():
    """Virtual seconds simulated per wall second must be >> 1 (Fig. 7).

    qps=2 gives a ~20 s virtual arrival span against sub-second wall time,
    so the >5x gate holds with an order-of-magnitude margin even on a
    loaded CI box (makespan is measured to the last completion, so wall
    noise no longer pads the numerator)."""
    res = run_mode("emulate", workload(n=40, qps=2.0), batch_s=20e-3)
    assert res.speedup > 5.0, f"speedup only {res.speedup:.1f}x"
    # sleep mode by construction runs at ~1x
    res_sleep = run_mode("sleep", workload(n=10, qps=20.0), batch_s=3e-3)
    assert res_sleep.speedup < 2.0


def test_all_requests_complete_exactly():
    reqs = workload(n=25, qps=50.0)
    res = run_mode("emulate", reqs)
    assert res.num_requests == 25
    assert len({r.request_id for r in reqs}) == 25
    for r in reqs:
        assert r.num_generated == r.max_new_tokens


# =========================================================================
# policy split (paper §6.2)
# =========================================================================

def test_policy_split_visible_in_tpot():
    """SGLang-style prefill prioritisation must show a worse decode tail
    than vLLM-style mixed batching under prefill pressure — the behavioural
    divergence the paper uses to argue for direct emulation."""
    wl = dict(n=40, qps=40.0)
    res_vllm = run_mode("emulate", workload(**wl), policy="vllm",
                        batch_s=5e-3)
    res_sgl = run_mode("emulate", workload(**wl), policy="sglang",
                       batch_s=5e-3)
    # decodes get starved while prefills are prioritised => worse TPOT tail
    assert res_sgl.tpot.p99 > res_vllm.tpot.p99 * 1.05, (
        f"sglang p99 TPOT {res_sgl.tpot.p99:.4f} vs vllm "
        f"{res_vllm.tpot.p99:.4f}")


def test_prefix_caching_reduces_prefill_work():
    shared = dict(n=30, qps=30.0, shared_prefix_len=64, prompt_len_mean=96)
    res_on = run_mode("emulate", workload(**shared), batch_s=5e-3)
    stack_off = build_stack(
        MODEL, engine_cfg(enable_prefix_caching=False), "emulate",
        predictor=StaticPredictor(5e-3), use_worker_group=False)
    try:
        res_off = BenchmarkRunner(stack_off.engine, workload(**shared),
                                  transport=stack_off.transport).run(120)
    finally:
        stack_off.shutdown()
    # with a StaticPredictor the *number of steps* falls (fewer prefill
    # chunks), so mean TTFT improves
    assert res_on.ttft.mean <= res_off.ttft.mean + 1e-9


# =========================================================================
# PD disaggregation on the unmodified engine (Table 1)
# =========================================================================

def test_disaggregated_cluster_end_to_end():
    from repro.core.client import LocalTransport, TimeJumpClient
    from repro.core.timekeeper import Timekeeper
    from repro.serving.disagg import DisaggConfig, DisaggregatedCluster
    from repro.serving.engine import LLMEngine
    from repro.serving.model_runner import TimeWarpModelRunner

    tk = Timekeeper(jitter_cooldown=0.0)
    tr = LocalTransport(tk)
    pre = LLMEngine(engine_cfg(), TimeWarpModelRunner(
        StaticPredictor(4e-3),
        TimeJumpClient(tr, "pre-w", auto_register=False)),
        tk.clock, name="prefill")
    dec = LLMEngine(engine_cfg(), TimeWarpModelRunner(
        StaticPredictor(4e-3),
        TimeJumpClient(tr, "dec-w", auto_register=False)),
        tk.clock, name="decode")

    cluster = DisaggregatedCluster(
        MODEL, pre, dec, DisaggConfig(kv_link_bandwidth=1e5), transport=tr)
    cluster.start()
    reqs = workload(n=12, qps=100.0)
    for r in reqs:
        cluster.submit(r)
    ok = cluster.wait_until_complete(12, timeout=60)
    cluster.stop()
    tk.close()
    assert ok, f"only {len(cluster.finished)}/12 finished"
    for r in cluster.finished:
        assert r.num_generated >= 1
    assert any(r.kv_transfer_time > 0 for r in cluster.finished), \
        "KV migration must consume virtual time"


# =========================================================================
# DES baseline divergence (the paper's motivation, Table 1 / §2.3)
# =========================================================================

def test_des_baseline_diverges_on_prefix_heavy_workload():
    """The Vidur-style DES has no prefix cache (Table 1 'VD' column): on a
    shared-prefix workload its TTFT diverges from the emulator, which runs
    the real radix-cache code.  This is the semantic gap §2.3 describes."""
    from repro.des.simulator import DESConfig, DiscreteEventSimulator

    shared = dict(n=30, qps=30.0, shared_prefix_len=96, prompt_len_mean=128,
                  max_prompt_len=256)
    res_emu = run_mode("emulate", workload(**shared), batch_s=5e-3)

    des = DiscreteEventSimulator(
        StaticPredictor(5e-3),
        DESConfig(max_num_seqs=16, max_batched_tokens=128))
    sims = des.run(workload(**shared))
    import numpy as np
    des_ttft_p50 = float(np.percentile(
        [s.ttft() for s in sims if s.ttft() is not None], 50))
    rel = abs(des_ttft_p50 - res_emu.ttft.p50) / max(res_emu.ttft.p50, 1e-9)
    assert rel > 0.05, (
        f"stale DES should diverge on prefix-heavy load (got {rel:.1%}) — "
        f"otherwise the paper's motivation would not reproduce")


# =========================================================================
# jitter cooldown (§4.2.1 Handling Message Jitter)
# =========================================================================

def test_jitter_cooldown_slows_but_stays_correct():
    stack = build_stack(MODEL, engine_cfg(), "emulate",
                        predictor=StaticPredictor(2e-3),
                        jitter_cooldown=2e-3, use_worker_group=False)
    try:
        reqs = workload(n=10, qps=50.0)
        res = BenchmarkRunner(stack.engine, reqs,
                              transport=stack.transport).run(120)
        assert res.num_requests == 10
        assert stack.timekeeper.stats.cooldown_waits > 0
    finally:
        stack.shutdown()


# =========================================================================
# TP worker group: collective barriers preserve rank causality
# =========================================================================

def test_worker_group_collective_exit_is_max_of_ranks():
    from repro.core.client import LocalTransport
    from repro.core.timekeeper import Timekeeper
    from repro.serving.workers import WorkerGroup

    tk = Timekeeper(jitter_cooldown=0.0)
    tr = LocalTransport(tk)
    # rank 1 is 50% slower (MoE imbalance): group exit = slowest rank
    wg = WorkerGroup(tr, 2, name="tp", jitter=[0.0, 0.5])
    t0 = tk.clock.now()
    wg.execute_step(0.1)
    elapsed = tk.clock.now() - t0
    assert elapsed >= 0.15 - 1e-6, "collective must exit at max(ranks)"
    wg.shutdown()
    tk.close()
