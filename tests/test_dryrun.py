"""Dry-run machinery: one real (small) cell lowers + compiles on the
production mesh in a subprocess (the main test process must keep 1 device),
and the artifact carries all roofline raw material.
"""

import json
import subprocess
import sys


def test_dryrun_cell_subprocess(tmp_path):
    out = tmp_path / "cell.jsonl"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "whisper_base", "--shape", "decode_32k",
         "--out", str(out)],
        capture_output=True, text=True, timeout=570,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
             # force CPU: an installed libtpu would probe cloud instance
             # metadata over the network (slow retries) before falling back
             "JAX_PLATFORMS": "cpu",
             # the minimal env drops the repo conftest's no-bytecode guard,
             # and this child imports half of src/ — keep it from littering
             # __pycache__ dirs that test_hygiene then rejects
             "PYTHONDONTWRITEBYTECODE": "1"})
    assert proc.returncode == 0, proc.stderr[-3000:]
    rec = json.loads(out.read_text().splitlines()[0])
    assert rec["mesh"] == "16x16" and rec["chips"] == 256
    assert rec["entry"] == "serve_step"
    assert rec["cost"]["flops"] > 0
    assert rec["cost"]["bytes_accessed"] > 0
    assert rec["memory"]["peak_per_device"] > 0
    assert rec["collectives"]["total_ops"] >= 0
    assert rec["collectives"]["unknown_trip_loops"] == 0, \
        "every while loop must carry a known trip count"


def test_all_cells_registry():
    from repro.configs import ARCH_IDS, all_cells, get_config
    cells = all_cells()
    assert len(cells) == 33                      # 40 - 7 long_500k skips
    assert len({a for a, _ in cells}) == 10
    # exactly the sub-quadratic archs run long_500k
    long_archs = {a for a, s in cells if s == "long_500k"}
    assert long_archs == {"mixtral_8x7b", "recurrentgemma_2b", "mamba2_370m"}
