"""Trip-count-aware HLO cost analysis: validation against XLA's own
cost_analysis on programs where XLA is correct (no loops), and against
ground truth where XLA is not (scanned loops).
"""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyze_hlo


def compile_(fn, *args, donate=()):
    return jax.jit(fn, donate_argnums=donate).lower(*args).compile()


def xla_cost(compiled) -> dict:
    """Normalize cost_analysis across jax versions (0.4.x returns [dict])."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        return ca[0] if ca else {}
    return ca


def test_matches_xla_on_scanfree_mlp():
    def mlp(x, w1, w2):
        return jax.nn.relu(x @ w1) @ w2

    a = jax.ShapeDtypeStruct((512, 1024), jnp.bfloat16)
    w1 = jax.ShapeDtypeStruct((1024, 4096), jnp.bfloat16)
    w2 = jax.ShapeDtypeStruct((4096, 1024), jnp.bfloat16)
    c = compile_(mlp, a, w1, w2)
    mine = analyze_hlo(c.as_text())
    xla = xla_cost(c)
    # XLA versions differ on elementwise/convert flop accounting (<0.5% on a
    # dot-dominated program); the dot flops themselves must agree exactly.
    assert mine.flops == pytest.approx(xla["flops"], rel=5e-3)
    # Bytes: the analyzer models HBM traffic at fusion boundaries; some XLA
    # versions additionally count fusion-internal operand reads, so assert a
    # band — at least the true argument/output traffic, never more than XLA.
    io_bytes = (512 * 1024 + 1024 * 4096 + 4096 * 1024 + 512 * 1024) * 2
    assert io_bytes <= mine.bytes <= xla["bytes accessed"] * 1.005


def test_scan_flops_weighted_by_trip_count():
    def single(x, w):
        return x @ w

    def scanned(x, ws):
        return jax.lax.scan(lambda h, w: (h @ w, None), x, ws)[0]

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 256, 256), jnp.float32)
    f1 = analyze_hlo(compile_(single, x, w).as_text()).flops
    f10 = analyze_hlo(compile_(scanned, x, ws).as_text()).flops
    assert f10 / f1 == pytest.approx(10.0, rel=0.01)
    # XLA's own analysis under-counts — this is the bug we correct
    xla10 = xla_cost(compile_(scanned, x, ws))["flops"]
    assert xla10 == pytest.approx(f1, rel=0.01)


def test_slice_dus_traffic_matches_xla():
    def slicer(big, idx):
        sl = jax.lax.dynamic_slice_in_dim(big, idx, 1, axis=0)
        return jax.lax.dynamic_update_slice_in_dim(big, sl * 2.0, idx, 0)

    big = jax.ShapeDtypeStruct((64, 1024, 1024), jnp.float32)  # 256 MB
    idx = jax.ShapeDtypeStruct((), jnp.int32)
    c = compile_(slicer, big, idx, donate=(0,))
    mine = analyze_hlo(c.as_text())
    xla = xla_cost(c)
    # must charge the 4 MB slice, not the 256 MB buffer
    assert mine.bytes == pytest.approx(xla["bytes accessed"], rel=1e-6)
    assert mine.bytes < 20e6


def test_scanned_weight_slices_charged_per_layer():
    """A layer scan must charge each iteration one layer's weights, not the
    whole stacked array."""
    def scan_model(x, ws):
        return jax.lax.scan(lambda h, w: (jnp.tanh(h @ w), None), x, ws)[0]

    x = jax.ShapeDtypeStruct((256, 1024), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 1024, 1024), jnp.float32)
    m = analyze_hlo(compile_(scan_model, x, ws).as_text())
    # pathological (pre-fix) accounting charges the full stacked array per
    # iteration: 8 iters x 32 MB = 268 MB; slice-aware is ~136 MB (slices,
    # activations and one-time copies)
    stacked = 8 * 1024 * 1024 * 4 * 8
    assert m.bytes < 0.6 * stacked, (
        "per-iteration weight traffic must be slice-sized")


def test_collectives_weighted_by_trip_count():
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((1,), ("model",))

    def scanned_psum(x, ws):
        def body(h, w):
            return jax.lax.psum(h @ w, "model"), None
        return jax.lax.scan(body, x, ws)[0]

    try:
        from jax import shard_map               # jax >= 0.6
        check_kw = {"check_vma": False}
    except ImportError:                         # jax 0.4/0.5 experimental API
        from jax.experimental.shard_map import shard_map
        check_kw = {"check_rep": False}
    f = shard_map(scanned_psum, mesh=mesh,
                  in_specs=(P(None, None), P(None, None, None)),
                  out_specs=P(None, None), **check_kw)
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
    m = analyze_hlo(compile_(f, x, ws).as_text())
    # 5 iterations x one (64,64) f32 all-reduce
    assert m.collective_bytes == pytest.approx(5 * 64 * 64 * 4, rel=0.01)
