"""Minimal stand-in for ``hypothesis`` when the real package is absent.

The property tests in this suite use a small, fixed subset of the hypothesis
API: ``@settings(max_examples=..., deadline=...)``, ``@given(...)`` with
either all-positional or all-keyword strategies, and the ``integers`` /
``floats`` / ``sampled_from`` / ``lists`` / ``tuples`` strategies.  This
module provides deterministic, seeded replacements: each ``@given`` test is
run against a fixed number of pseudo-random samples drawn from the declared
strategies.  It is *not* a property-testing engine (no shrinking, no coverage
guidance) — install ``hypothesis`` (see requirements-dev.txt) for the real
thing.  Usage in a test module::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:                       # optional dev dependency
        from _hypothesis_compat import given, settings, st
"""

from __future__ import annotations

import functools
import inspect
import random

_DEFAULT_EXAMPLES = 10  # per-test cap when no @settings is applied


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


class _StrategiesModule:
    """Namespace mimicking ``hypothesis.strategies``."""

    @staticmethod
    def integers(min_value=0, max_value=1 << 16):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    @staticmethod
    def sampled_from(elements):
        seq = list(elements)
        return _Strategy(lambda rng: rng.choice(seq))

    @staticmethod
    def lists(elements, min_size=0, max_size=10):
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elements.example(rng) for _ in range(n)]
        return _Strategy(draw)

    @staticmethod
    def tuples(*elements):
        return _Strategy(lambda rng: tuple(e.example(rng) for e in elements))

    @staticmethod
    def just(value):
        return _Strategy(lambda rng: value)


st = _StrategiesModule()


def settings(max_examples=_DEFAULT_EXAMPLES, deadline=None, **_kw):
    """Record ``max_examples`` for a subsequent (or prior) @given."""

    def deco(fn):
        # @settings may wrap either the raw test or the @given-wrapped one;
        # stash the knob where _run_examples can find it either way.
        target = getattr(fn, "__wrapped_test__", fn)
        target.__compat_max_examples__ = max_examples
        return fn

    return deco


def given(*arg_strategies, **kw_strategies):
    def deco(fn):
        @functools.wraps(fn)
        def runner(*fixture_args, **fixture_kw):
            n = getattr(runner, "__compat_max_examples__",
                        getattr(fn, "__compat_max_examples__",
                                _DEFAULT_EXAMPLES))
            n = min(n, _DEFAULT_EXAMPLES)
            rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
            for _ in range(n):
                drawn_args = tuple(s.example(rng) for s in arg_strategies)
                drawn_kw = {k: s.example(rng)
                            for k, s in kw_strategies.items()}
                fn(*fixture_args, *drawn_args, **fixture_kw, **drawn_kw)

        runner.__wrapped_test__ = fn
        # Hide strategy-drawn parameters from pytest's fixture resolution:
        # expose only the params *not* supplied by a strategy.
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())[len(arg_strategies):]
        params = [p for p in params if p.name not in kw_strategies]
        runner.__signature__ = sig.replace(parameters=params)
        del runner.__wrapped__  # set by functools.wraps; re-leaks the sig
        return runner

    return deco
