"""kv_append="defer" (§Perf kv_defer_append) must be numerically equivalent
to the inline per-layer append: same logits for chunked prefill and decode,
and the deferred cache must equal the inline cache after the write.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.models.transformer import build_model

ARCHS = ["qwen2_5_3b", "mixtral_8x7b", "recurrentgemma_2b", "olmo_1b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_defer_matches_inline(arch):
    cfg_in = get_reduced_config(arch)
    cfg_df = cfg_in.replace(kv_append="defer")
    m_in = build_model(cfg_in)
    m_df = build_model(cfg_df)
    key = jax.random.key(0)
    params = m_in.init(key, jnp.float32)

    B, T = 2, 12
    toks = np.asarray(jax.random.randint(key, (B, T + 4), 0,
                                         cfg_in.vocab_size))

    def run(model):
        cache = model.init_cache(B, 64, jnp.float32)
        # chunked prefill: 2 chunks
        l1, cache = model.prefill(
            params, {"tokens": jnp.asarray(toks[:, :T // 2])}, cache)
        l2, cache = model.prefill(
            params, {"tokens": jnp.asarray(toks[:, T // 2:T])}, cache)
        # a few decode steps
        logits = [l2]
        for t in range(T, T + 4):
            l, cache = model.decode_step(params, cache,
                                         jnp.asarray(toks[:, t:t + 1]))
            logits.append(l)
        return logits, cache

    logits_in, cache_in = run(m_in)
    logits_df, cache_df = run(m_df)
    for a, b in zip(logits_in, logits_df):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-3,
                                   err_msg=f"{arch}: defer diverges")
    # caches identical after the deferred write lands
    for leaf_a, leaf_b in zip(jax.tree.leaves(cache_in),
                              jax.tree.leaves(cache_df)):
        np.testing.assert_allclose(np.asarray(leaf_a, np.float32),
                                   np.asarray(leaf_b, np.float32),
                                   rtol=2e-4, atol=2e-4)
