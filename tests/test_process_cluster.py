"""Process-mode cluster runtime: replicas as OS processes over the
time-warp socket transport.

Covers the cross-process control plane end to end: submit/completion frames
(the pre-barrier ack invariant closed-loop sessions build on), same-seed
parity with the thread backend, drain/add over the wire (warm-pool
activation), and ReplicaView probes answered by the child's live engine.

These tests spawn real child processes (multiprocessing ``spawn``), so they
are wall-slower than the rest of the suite and carry pytest-timeout markers:
a wedged barrier or a hung child must fail, not freeze, CI.
"""

import pytest

from repro.cluster import (Autoscaler, AutoscalerConfig, ProcessCluster,
                           SchedulePolicy, build_cluster)
from repro.configs import get_reduced_config
from repro.core.predictor import StaticPredictor
from repro.serving.benchmark import BenchmarkRunner
from repro.serving.scheduler import EngineConfig
from repro.workload import (SessionConfig, SessionWorkload, WorkloadConfig,
                            synthesize)

pytestmark = pytest.mark.timeout(300)

MODEL = get_reduced_config("qwen2_5_3b")
# Deliberately slow predictor step: socket round trips absorb wall time
# into the virtual timeline (Eq. 1), and the parity bar is "within one of
# these" — same methodology as benchmarks/fig_distributed.py.
STEP = 50e-3


def engine_cfg(**kw):
    base = dict(policy="vllm", max_num_seqs=8, max_batched_tokens=64,
                block_size=4, num_blocks=4096, enable_prefix_caching=False)
    base.update(kw)
    return EngineConfig(**base)


def workload(n=8, qps=8.0, seed=3, **kw):
    base = dict(num_requests=n, qps=qps, prompt_len_mean=24,
                output_len_mean=6, max_prompt_len=48, max_output_len=10,
                seed=seed)
    base.update(kw)
    return synthesize(WorkloadConfig(**base))


def build(replicas, *, backend="process", step=STEP, warm=None, **kw):
    return build_cluster(MODEL, engine_cfg(), replicas, policy="round_robin",
                         predictor=StaticPredictor(step), backend=backend,
                         warm_replicas=warm, **kw)


def drive(cluster, reqs, *, autoscaler=None, timeout=120.0):
    return BenchmarkRunner(cluster, reqs, transport=cluster.transport,
                           autoscaler=autoscaler).run(timeout=timeout)


# =========================================================================
# basics
# =========================================================================

def test_process_cluster_serves_open_loop():
    cluster = build(2)
    try:
        assert isinstance(cluster, ProcessCluster)
        res = drive(cluster, workload(n=8))
        assert res.num_requests == 8
        assert res.num_replicas == 2
        # round robin over two live child processes
        assert sorted(set(cluster.router.decisions)) == [0, 1]
        per_replica = [h.stats()["finished"] for h in cluster.engines]
        assert sum(per_replica) == 8 and all(c > 0 for c in per_replica)
        # step accounting crossed the wire
        assert len(cluster.step_log) > 0
        assert res.ttft.p50 > 0
        # virtual time ran ahead of wall time (the point of the exercise)
        assert res.makespan_virtual > res.wall_seconds
    finally:
        cluster.shutdown()


def test_process_replica_probes_answer_from_child_engine():
    """ReplicaView probes are real RPCs into the child's engine counters:
    zero when idle, zero again once submitted work completed (a mid-flight
    nonzero read exists but is wall-racy, so not asserted), and the
    parent-side in-flight ledger empties exactly at the completion frames."""
    cluster = build(2)
    try:
        cluster.start()
        h = cluster.engines[0]
        assert h.num_outstanding() == 0
        assert h.outstanding_tokens() == 0
        assert h.prefix_match_len([1, 2, 3]) == 0
        reqs = workload(n=4, qps=1e6)
        for r in reqs:
            cluster.submit(r)
        assert cluster.wait_until_complete(4, timeout=60)
        assert h.num_outstanding() == 0
        assert h.outstanding_tokens() == 0
        assert h.in_flight_ids() == set()
        assert sum(x.stats()["finished"] for x in cluster.engines) == 4
    finally:
        cluster.shutdown()


def test_process_cluster_rejects_incompatible_modes():
    from repro.core.clock import ManualWallSource
    with pytest.raises(AssertionError):
        build_cluster(MODEL, engine_cfg(), 2, backend="process", mode="sleep",
                      predictor=StaticPredictor(STEP))
    with pytest.raises(AssertionError):
        build_cluster(MODEL, engine_cfg(), 2, backend="process",
                      predictor=StaticPredictor(STEP),
                      wall=ManualWallSource())
    with pytest.raises(AssertionError):
        build_cluster(MODEL, engine_cfg(), 2, backend="process",
                      policy="pd_pool", predictor=StaticPredictor(STEP))
    with pytest.raises(AssertionError):
        build_cluster(MODEL, engine_cfg(), 2, backend="nope",
                      predictor=StaticPredictor(STEP))


# =========================================================================
# same-seed parity with the thread backend (the acceptance bar)
# =========================================================================

def test_process_backend_matches_thread_backend_same_seed():
    """Identical routing decisions; per-request TTFT/TPOT within one
    slow-step — the repo's analogue of the paper's distributed-causality
    claim, also asserted at benchmark scale by fig_distributed.  One
    ``repro.scenario.compare`` call replaces the hand-rolled two-backend
    plumbing: the scenario spec carries the whole cell."""
    from repro.scenario import compare, scenario_with, get_preset

    scenario = scenario_with(
        get_preset("distributed_parity"),
        name="process_thread_parity",
        **{"workload.arrival": "poisson",     # queued regime, same bar
           "workload.qps": 6.0,
           "workload.num_requests": 12,
           "workload.output_len_mean": 6.0,
           "workload.max_output_len": 10,
           "pool.step_time_s": STEP,
           "seed": 11})
    cres = compare(scenario, backends=("thread", "process"), timeout=120)
    assert cres.decisions_equal
    assert cres.max_err_steps <= 1.0
    assert cres.results["thread"].num_requests == 12
    assert cres.results["process"].num_requests == 12


# =========================================================================
# closed loop over the wire
# =========================================================================

def test_process_closed_loop_sessions_complete_all_turns():
    """The cross-process completion-listener path: each finished turn's
    completion frame reaches the runner (which registers the think-time
    actor) BEFORE the child replica re-enters the barrier — so no follow-up
    is ever skipped over, and release-rule causality holds exactly."""
    sw = SessionWorkload(SessionConfig(
        num_sessions=4, qps=3.0, turns_mean=2.5, max_turns=3,
        think_time_mean=0.2, prompt_len_mean=30, followup_len_mean=10,
        output_len_mean=6, max_output_len=10, seed=7))
    cluster = build(2, step=5e-3)
    try:
        res = drive(cluster, sw)
        assert res.num_requests == sw.total_requests
        assert res.num_sessions == sw.num_sessions
        by_session = {}
        for r in cluster.finished:
            by_session.setdefault(r.session_id, {})[r.turn_index] = r
        checked = 0
        for sid, turns in by_session.items():
            for k, r in turns.items():
                if k == 0:
                    continue
                prev = turns[k - 1]
                think = sw.sessions[sw._index_of(sid)].turns[k].think_time
                assert r.arrival_time >= prev.finish_time + think - 1e-6
                checked += 1
        assert checked > 0, "workload produced no multi-turn sessions"
    finally:
        cluster.shutdown()


# =========================================================================
# elastic membership over the wire
# =========================================================================

def test_drain_replica_over_the_wire():
    """Drain = stop routing → in-flight completion frames → retire
    (deregister) frame; drained child keeps its stats reachable."""
    cluster = build(2, step=5e-3)
    try:
        cluster.start()
        reqs = workload(n=10, qps=1e6)
        for r in reqs[:6]:
            cluster.submit(r)
        cluster.drain_replica(1)
        assert cluster.num_active() == 1
        for r in reqs[6:]:
            cluster.submit(r)
        assert cluster.wait_until_complete(10, timeout=60)
        assert all(d == 0 for d in cluster.router.decisions[6:])
        assert len(cluster.finished) == 10
        m = cluster.membership_events()[1]
        assert m["drain_started"] is not None
        assert m["drained"] is not None and m["drained"] >= m["drain_started"]
        assert cluster.engines[1].retired
        # post-drain: the child process is alive and still answers stats
        assert cluster.engines[1].stats()["finished"] > 0
        with pytest.raises(ValueError):
            cluster.drain_replica(1)
    finally:
        cluster.shutdown()


def test_autoscaler_activates_warm_standby_and_drains():
    """Scripted scale-up activates a pre-spawned warm child (one
    start_engine frame — no process-spawn wall time mid-run), serves work,
    then the scripted scale-down retires it over the wire."""
    sw = SessionWorkload(SessionConfig(
        num_sessions=5, qps=3.0, turns_mean=3.0, max_turns=4,
        think_time_mean=0.3, prompt_len_mean=30, followup_len_mean=10,
        output_len_mean=6, max_output_len=10, seed=29))
    cluster = build(1, step=5e-3, warm=2)
    assert cluster.warm_available == 1
    asc = Autoscaler(cluster, SchedulePolicy([(0.2, +1), (1.2, -1)]),
                     AutoscalerConfig(interval_s=0.1, provision_delay_s=0.1,
                                      min_replicas=1, max_replicas=2))
    try:
        res = drive(cluster, sw, autoscaler=asc)
        assert res.num_requests == sw.total_requests
        assert len(cluster.engines) == 2, "scale-up never happened"
        assert cluster.warm_available == 0, "warm standby was not activated"
        assert any(d == 1 for _, d, _ in asc.decision_log)
        joined = cluster.membership_events()[1]
        assert joined["added"] is not None
        # the activated replica actually served traffic
        assert cluster.engines[1].stats()["finished"] > 0
        drained = [m["replica"] for m in cluster.membership_events()
                   if m["drained"] is not None]
        assert drained in ([], [1])   # drain may land in the final window
    finally:
        cluster.shutdown()
