"""Virtual time protocol: correctness properties of Timekeeper + TimeJump.

These encode the paper's §4.2.1 guarantees:
  * monotonicity — virtual time never goes backwards,
  * minimum-advancement — a barrier round advances exactly to the smallest
    pending target (causality),
  * per-call postcondition — TIMEJUMP(Δt) returns only once virtual time
    reached its absolute target,
  * graceful degradation — a stalled actor costs wall time, never
    correctness,
  * elasticity — actor departure re-evaluates the barrier.
"""

import threading
import time

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                              # optional dev dependency
    from _hypothesis_compat import given, settings, st

from repro.core.client import LocalTransport, TimeJumpClient
from repro.core.timekeeper import Timekeeper


def make_tk(cooldown=0.0):
    tk = Timekeeper(jitter_cooldown=cooldown)
    return tk, LocalTransport(tk)


def test_single_actor_jump_exact():
    tk, tr = make_tk()
    c = TimeJumpClient(tr, "a")
    t0 = c.now()
    t1 = c.time_jump(0.5)
    assert t1 >= t0 + 0.5
    # and it was a jump, not a sleep: virtually instant in wall time
    c.deregister()


def test_two_actor_min_advancement():
    """W_A jumps 50ms, W_B jumps 10ms: the barrier must advance by 10ms
    first; A's single call spans multiple rounds (paper §4.2.1 example).

    Manual wall source: virtual time then advances *only* through barrier
    jumps, so the min-advancement spacing is exact instead of carrying
    wall-rate drift from OS scheduling stalls between rounds."""
    from repro.core.clock import ManualWallSource, VirtualClock
    tk = Timekeeper(clock=VirtualClock(ManualWallSource()),
                    jitter_cooldown=0.0)
    tr = LocalTransport(tk)
    a = TimeJumpClient(tr, "A")
    b = TimeJumpClient(tr, "B")
    observed = []

    def run_b():
        for _ in range(5):
            observed.append(b.time_jump(0.010))

    def run_a():
        a.time_jump(0.050)

    ta = threading.Thread(target=run_a)
    tb = threading.Thread(target=run_b)
    ta.start(); tb.start(); ta.join(); tb.join()
    # B's successive returns must be ~10ms apart (min-advancement), and
    # A's 50ms target is reached exactly when B has done 5 x 10ms.
    for i, t in enumerate(observed):
        assert t == pytest.approx(observed[0] + 0.010 * i, abs=2e-3)
    a.deregister(); b.deregister()


def test_jump_postcondition_and_monotonic():
    tk, tr = make_tk()
    c = TimeJumpClient(tr, "solo")
    last = c.now()
    for dt in (0.001, 0.1, 0.0, 0.025, 1.0):
        ret = c.time_jump(dt)
        assert ret >= last + dt - 1e-9
        assert ret >= last
        last = ret
    c.deregister()


def test_graceful_degradation_wall_rate():
    """A registered-but-silent actor degrades peers to sleep-based speed:
    correct result, wall-clock cost (paper: 'slow but never incorrect')."""
    tk, tr = make_tk()
    lazy = TimeJumpClient(tr, "lazy")   # never jumps
    act = TimeJumpClient(tr, "active")
    t0w = time.monotonic()
    t0v = act.now()
    t1v = act.time_jump(0.08)
    elapsed_wall = time.monotonic() - t0w
    assert t1v - t0v >= 0.08 - 1e-6        # correct virtual advance
    assert elapsed_wall >= 0.07            # paid in wall time
    lazy.deregister(); act.deregister()


def test_elastic_deregistration_unblocks_barrier():
    tk, tr = make_tk()
    a = TimeJumpClient(tr, "a")
    b = TimeJumpClient(tr, "b")
    done = threading.Event()

    def run_a():
        a.time_jump(0.02)
        done.set()

    t = threading.Thread(target=run_a)
    t.start()
    time.sleep(0.005)
    assert not done.is_set()      # a is barrier-blocked on b
    b.deregister()                # departure must resolve the barrier
    t.join(timeout=1.0)
    assert done.is_set()
    a.deregister()


def test_concurrent_speedup():
    """The headline mechanic: N actors x many jumps without wall-clock cost.

    Hardened with the ManualWallSource treatment (same as
    test_two_actor_min_advancement): wall time never flows on its own, so a
    correct protocol run advances virtual time to *exactly* the concurrent
    jump total (1.0 s) while the wall source reads 0 — structurally infinite
    speedup, jumps not sleeps.  A regression to the degradation path
    (riding the wall-clock timeout instead of the barrier) cannot terminate
    under a frozen wall except through barrier resolutions, and any
    over-advancement (double-resolved round, skipped minimum) shows up as
    virt != 1.0 exactly.  The old wall-clock ratio assertion (>8x) flaked on
    loaded 2-core CI boxes; the manual-wall formulation has no timing
    dependence at all — the only wall-clock artefact left is the bounded
    join that turns a wedge into a failure instead of a hang."""
    from repro.core.clock import ManualWallSource, VirtualClock

    tk = Timekeeper(clock=VirtualClock(ManualWallSource()),
                    jitter_cooldown=0.0)
    tr = LocalTransport(tk)
    clients = [TimeJumpClient(tr, f"w{i}") for i in range(4)]
    t0v = tk.clock.now()
    wall0 = tk.clock.wall.time()

    def run(c):
        for _ in range(50):
            c.time_jump(0.02)   # 1 virtual second each
        c.deregister()          # departure re-evaluates the barrier

    threads = [threading.Thread(target=run, args=(c,)) for c in clients]
    for t in threads: t.start()
    for t in threads:
        t.join(timeout=30)
        assert not t.is_alive(), "barrier wedged: jump never completed"
    virt = tk.clock.now() - t0v
    assert virt == pytest.approx(1.0, abs=1e-9), \
        f"virtual advance {virt} != 1.0: over/under-advanced barrier"
    assert tk.clock.wall.time() == wall0, "manual wall must never flow"
    assert tk.stats.rounds >= 50        # many coordinated resolutions


def test_jitter_cooldown_spacing():
    """With cooldown J, consecutive clock advances are >= J apart in wall
    time (bounded-jitter model, §4.2.1)."""
    tk, tr = make_tk(cooldown=0.002)
    c = TimeJumpClient(tr, "a")
    stamps = []
    orig = tk.clock.advance_to

    def wrapped(t):
        stamps.append(time.monotonic())
        return orig(t)

    tk.clock.advance_to = wrapped
    for _ in range(5):
        c.time_jump(0.01)
    gaps = [b - a for a, b in zip(stamps, stamps[1:])]
    assert all(g >= 0.0015 for g in gaps), gaps
    assert tk.stats.cooldown_waits >= 1
    c.deregister()


@settings(max_examples=20, deadline=None)
@given(
    ops=st.lists(
        st.tuples(st.integers(min_value=0, max_value=2),      # churner index
                  st.sampled_from(["park", "unpark", "deregister",
                                   "reregister"])),
        min_size=1, max_size=12,
    )
)
def test_park_deregister_churn_never_wedges_or_overadvances(ops):
    """Timekeeper elasticity under churn (autoscaler add/drain at speed):
    concurrent park/unpark/deregister against a *pending* barrier must
    never wedge the driver (its jumps all complete) and never double-resolve
    a round (with a manual wall the driver's total virtual advance is
    *exactly* the sum of its jumps — any over-advance means a barrier
    resolved past a pending actor's target)."""
    from repro.core.clock import ManualWallSource, VirtualClock

    tk = Timekeeper(clock=VirtualClock(ManualWallSource()),
                    jitter_cooldown=0.0)
    tr = LocalTransport(tk)
    driver = TimeJumpClient(tr, "driver")
    churners = [TimeJumpClient(tr, f"churn-{i}") for i in range(3)]
    jumps = [0.003, 0.007, 0.002, 0.005]
    t0 = tk.clock.now()
    done = threading.Event()

    def drive():
        for dt in jumps:
            driver.time_jump(dt)
        done.set()

    t = threading.Thread(target=drive)
    t.start()
    # Churn against the pending barrier from this thread.  Registered
    # churners never jump, so the driver's progress depends entirely on
    # park/deregister re-evaluating the barrier correctly.
    for idx, op in ops:
        c = churners[idx]
        if op == "park":
            c.park()
        elif op == "unpark":
            c.unpark()
        elif op == "deregister":
            c.deregister()
        else:
            c.register()
    # Cleanup pass: whatever state the ops left, every churner departs; the
    # driver must then complete all jumps without any wall time flowing.
    for c in churners:
        c.deregister()
    t.join(timeout=30)
    assert done.is_set(), "driver wedged behind parked/deregistered churners"
    advanced = tk.clock.now() - t0
    assert advanced == pytest.approx(sum(jumps), abs=1e-9), \
        f"advanced {advanced} != {sum(jumps)}: round double-resolved"
    assert tk.clock.wall.time() == 0.0
    driver.deregister()
    tk.close()


@settings(max_examples=25, deadline=None)
@given(
    jump_lists=st.lists(
        st.lists(st.floats(min_value=1e-4, max_value=0.05), min_size=1, max_size=6),
        min_size=1, max_size=4,
    )
)
def test_property_virtual_elapsed_bounds(jump_lists):
    """For concurrent actors registered up-front, the total virtual advance
    is at least max_i(sum of i's jumps) (every actor reaches its target) and
    at most max_i(...) + wall_elapsed + eps (time can only additionally flow
    at wall rate — no spurious jumps)."""
    tk, tr = make_tk()
    clients = [TimeJumpClient(tr, f"w{i}") for i in range(len(jump_lists))]
    t0v = tk.clock.now()
    t0w = time.monotonic()

    def run(c, jumps):
        for dt in jumps:
            c.time_jump(dt)
        c.deregister()

    threads = [threading.Thread(target=run, args=(c, js))
               for c, js in zip(clients, jump_lists)]
    for t in threads: t.start()
    for t in threads: t.join()
    wall = time.monotonic() - t0w
    virt = tk.clock.now() - t0v
    need = max(sum(js) for js in jump_lists)
    assert virt >= need - 1e-9
    assert virt <= need + wall + 0.05
