"""Cluster layer tests: router policies, multi-replica determinism, and
emulator-vs-DES parity at cluster scale.

Determinism methodology: the reproducibility tests inject a
:class:`ManualWallSource`, under which wall time never flows on its own —
virtual time advances *only* through Timekeeper-coordinated jumps, so two
identical cluster runs must produce bit-identical virtual request timelines
(the barrier protocol serialises every step).  With a real wall clock the
timeline additionally absorbs scheduler CPU time at wall rate, which is the
emulator's modelling of control-plane overhead, not nondeterminism.
"""

import copy
import threading

import pytest

from repro.cluster import (Cluster, LeastOutstandingTokensRouter, PDPoolRouter,
                           PrefixAffinityRouter, RoundRobinRouter,
                           build_cluster, make_router)
from repro.cluster.router import ROUTER_POLICIES
from repro.configs import get_reduced_config
from repro.core.client import TimeJumpClient
from repro.core.clock import ManualWallSource
from repro.core.predictor import StaticPredictor
from repro.des.simulator import DESConfig, DiscreteEventSimulator
from repro.serving.benchmark import BenchmarkRunner
from repro.serving.scheduler import EngineConfig
from repro.workload import WorkloadConfig, synthesize

MODEL = get_reduced_config("qwen2_5_3b")
DT = 5e-3                               # StaticPredictor step duration


def engine_cfg(**kw):
    base = dict(policy="vllm", max_num_seqs=8, max_batched_tokens=64,
                block_size=4, num_blocks=4096)
    base.update(kw)
    return EngineConfig(**base)


def workload(n=16, qps=40.0, seed=3, **kw):
    base = dict(num_requests=n, qps=qps, prompt_len_mean=24,
                output_len_mean=8, max_prompt_len=48, max_output_len=12,
                seed=seed)
    base.update(kw)
    return synthesize(WorkloadConfig(**base))


# =========================================================================
# router policy units (no engines needed: fake views)
# =========================================================================

class FakeView:
    def __init__(self, outstanding=0, prefix=None):
        self._out = outstanding
        self._prefix = prefix or {}

    def outstanding_tokens(self):
        return self._out

    def prefix_match_len(self, tokens):
        return self._prefix.get(tuple(tokens[:4]), 0)


class FakeReq:
    def __init__(self, tokens, out=8):
        self.prompt_tokens = list(tokens)
        self.max_new_tokens = out


def test_round_robin_cycles():
    r = RoundRobinRouter(3)
    views = [FakeView() for _ in range(3)]
    picks = [r.route(FakeReq([i]), views) for i in range(7)]
    assert picks == [0, 1, 2, 0, 1, 2, 0]


def test_least_outstanding_balances_skewed_loads():
    """Under skewed prompt lengths the token-aware policy places onto the
    genuinely least-loaded replica, not just the fewest-requests one."""
    r = LeastOutstandingTokensRouter(3)
    views = [FakeView(outstanding=900), FakeView(outstanding=50),
             FakeView(outstanding=400)]
    assert r.route(FakeReq(range(8)), views) == 1
    # deterministic tie-break: lowest index wins
    views = [FakeView(outstanding=7), FakeView(outstanding=7), FakeView(9)]
    assert r.route(FakeReq(range(8)), views) == 0


def test_prefix_affinity_prefers_cache_hits():
    r = PrefixAffinityRouter(2)
    key = (1, 2, 3, 4)
    views = [FakeView(outstanding=500),
             FakeView(outstanding=0, prefix={})]
    views[0]._prefix = {key: 16}         # replica 0 holds the prefix
    # despite higher load, the cache-holding replica wins
    assert r.route(FakeReq([1, 2, 3, 4, 5, 6]), views) == 0


def test_prefix_affinity_sticky_before_cache_warm():
    """Shared-prompt session requests co-locate even when no replica has
    cached the prefix yet (probe returns 0 everywhere): the first placement
    is remembered by prompt head."""
    r = PrefixAffinityRouter(4)
    views = [FakeView(outstanding=o) for o in (5, 3, 9, 3)]
    shared = list(range(100, 140))
    first = r.route(FakeReq(shared + [1]), views)
    assert first == 1                    # least outstanding, lowest index
    # loads shift, but the session stays put
    views = [FakeView(outstanding=o) for o in (0, 99, 0, 0)]
    for suffix in ([2], [3, 4], [5]):
        assert r.route(FakeReq(shared + suffix), views) == first
    # a different session routes independently: with replica 1 now heavily
    # loaded, the fresh session must land somewhere else
    assert r.route(FakeReq(list(range(500, 540)), 4), views) != 1


def test_pd_pool_splits_and_routes():
    r = PDPoolRouter(4)                  # 2 prefill + 2 decode
    assert r.prefill_indices == [0, 1] and r.decode_indices == [2, 3]
    views = [FakeView(outstanding=o) for o in (9, 2, 50, 1)]
    assert r.route(FakeReq(range(8)), views) == 1          # prefill pool only
    assert r.route_decode(FakeReq(range(8)), views) == 3   # decode pool only
    assert r.intake_indices() == [0, 1]


def test_make_router_registry():
    assert set(ROUTER_POLICIES) == {
        "round_robin", "least_outstanding_tokens", "cost_normalized_load",
        "prefix_affinity", "pd_pool", "adapter_affinity"}
    with pytest.raises(ValueError):
        make_router("nope", 2)


# =========================================================================
# cluster end-to-end: routing behaviour with real engines
# =========================================================================

def drive_cluster(cluster, reqs, timeout=120.0):
    cluster.start()
    disp = TimeJumpClient(cluster.transport, "dispatcher")
    t0 = cluster.clock.now()
    try:
        for r in sorted(reqs, key=lambda r: r.arrival_time):
            disp.jump_to(t0 + r.arrival_time)
            r.arrival_time = cluster.clock.now()
            cluster.submit(r)
    finally:
        disp.deregister()
    ok = cluster.wait_until_complete(len(reqs), timeout=timeout)
    assert ok, f"cluster did not drain: {len(cluster.finished)}/{len(reqs)}"
    return cluster


def test_cluster_prefix_affinity_colocates_sessions():
    """Sessions sharing a long system prompt must all land on one replica
    (where the radix cache holds their prefix); per-replica hit rates prove
    the KV was actually reused, not just co-located."""
    reqs = workload(n=20, qps=30.0, shared_prefix_len=32,
                    prompt_len_mean=40, max_prompt_len=64)
    cluster = build_cluster(MODEL, engine_cfg(), 2, policy="prefix_affinity",
                            predictor=StaticPredictor(DT))
    try:
        drive_cluster(cluster, reqs)
        decisions = cluster.router.decisions
        assert len(set(decisions)) == 1, \
            f"shared-prefix sessions scattered across replicas: {decisions}"
        target = cluster.engines[decisions[0]]
        assert target.prefix_cache.stats.hit_tokens > 0, \
            "co-location must produce actual prefix-cache hits"
    finally:
        cluster.shutdown()


def test_cluster_least_outstanding_balances():
    """Distinct-prompt traffic must spread across replicas under the
    token-aware policy (no starvation of either replica)."""
    reqs = workload(n=24, qps=60.0)
    cluster = build_cluster(MODEL, engine_cfg(), 2,
                            policy="least_outstanding_tokens",
                            predictor=StaticPredictor(DT))
    try:
        drive_cluster(cluster, reqs)
        per_replica = [e.stats()["finished"] for e in cluster.engines]
        assert sum(per_replica) == 24
        assert min(per_replica) >= 24 // 4, \
            f"least-outstanding starved a replica: {per_replica}"
    finally:
        cluster.shutdown()


def test_cluster_pd_pool_migrates_kv():
    reqs = workload(n=10, qps=80.0)
    cluster = build_cluster(MODEL, engine_cfg(), 2, policy="pd_pool",
                            predictor=StaticPredictor(DT),
                            kv_link_bandwidth=1e5)   # slow link: visible time
    try:
        drive_cluster(cluster, reqs)
        assert all(r.kv_migrated for r in cluster.finished)
        assert any(r.kv_transfer_time > 0 for r in cluster.finished), \
            "KV migration must consume virtual time"
        # prefill replicas never decode beyond the first token
        for i in cluster.router.prefill_indices:
            for rec in cluster.engines[i].step_log:
                assert rec.num_decode == 0
    finally:
        cluster.shutdown()


def test_cluster_rejects_mixed_clocks():
    a = build_cluster(MODEL, engine_cfg(), 1, predictor=StaticPredictor(DT))
    b = build_cluster(MODEL, engine_cfg(), 1, predictor=StaticPredictor(DT))
    try:
        with pytest.raises(AssertionError):
            Cluster([a.engines[0], b.engines[0]], RoundRobinRouter(2))
    finally:
        a.shutdown()
        b.shutdown()


# =========================================================================
# determinism: identical runs -> identical virtual timelines
# =========================================================================

def _timeline(num_replicas, policy, seed=11):
    """Run a cluster on a manual wall source; return the per-request
    virtual-time timeline {request index -> (arrival, first_token, finish)}."""
    reqs = workload(n=12, qps=50.0, seed=seed)
    order = {r.request_id: i for i, r in enumerate(reqs)}
    cluster = build_cluster(
        MODEL, engine_cfg(), num_replicas, policy=policy,
        predictor=StaticPredictor(DT), wall=ManualWallSource())
    try:
        drive_cluster(cluster, reqs)
        return {
            order[r.request_id]:
                (r.arrival_time, r.first_token_time, r.finish_time)
            for r in cluster.finished
        }, list(cluster.router.decisions)
    finally:
        cluster.shutdown()


def test_cluster_determinism_identical_timelines():
    """Two identical 2-replica runs produce *identical* virtual-time request
    timelines (arrival/TTFT/finish) and identical routing decisions."""
    tl1, dec1 = _timeline(2, "round_robin")
    tl2, dec2 = _timeline(2, "round_robin")
    assert dec1 == dec2
    assert tl1.keys() == tl2.keys()
    for k in tl1:
        a1, f1, e1 = tl1[k]
        a2, f2, e2 = tl2[k]
        assert a1 == pytest.approx(a2, abs=1e-9)
        assert f1 == pytest.approx(f2, abs=1e-9)
        assert e1 == pytest.approx(e2, abs=1e-9)


# =========================================================================
# emulator-vs-DES parity at cluster scale (§2.3 extended)
# =========================================================================

def test_two_replica_emulator_matches_two_replica_des():
    """Same workload, same router policy, same predictor: the 2-replica
    emulator and the 2-replica DES agree on completed-request count, and
    per-request virtual finish latencies agree within the predictor's own
    step granularity (StaticPredictor: one step = DT)."""
    reqs = workload(n=16, qps=40.0)
    reqs_des = copy.deepcopy(reqs)

    cluster = build_cluster(
        MODEL, engine_cfg(enable_prefix_caching=False), 2,
        policy="round_robin", predictor=StaticPredictor(DT),
        wall=ManualWallSource())
    try:
        drive_cluster(cluster, reqs)
        emu_latency = {r.request_id: r.e2e_latency()
                       for r in cluster.finished}
    finally:
        cluster.shutdown()

    des = DiscreteEventSimulator(
        StaticPredictor(DT),
        DESConfig(max_num_seqs=8, max_batched_tokens=64, step_overhead_s=0.0),
        num_replicas=2, router=make_router("round_robin", 2))
    sims = des.run(reqs_des)

    assert len(emu_latency) == len(reqs)
    assert sum(1 for s in sims if s.finish_time is not None) == len(reqs)
    for orig, sim in zip(reqs_des, sims):
        des_latency = sim.finish_time - sim.arrival_time
        err = abs(emu_latency[orig.request_id] - des_latency)
        assert err <= DT + 1e-9, \
            (f"request {orig.request_id}: emulator/DES finish diverges by "
             f"{err / DT:.2f} steps")


def test_des_single_replica_unchanged():
    """num_replicas=1 must reproduce the pre-refactor single-engine DES."""
    reqs = workload(n=10, qps=30.0, seed=5)
    des = DiscreteEventSimulator(
        StaticPredictor(DT), DESConfig(max_num_seqs=8, max_batched_tokens=64))
    sims = des.run(reqs)
    assert all(s.finish_time is not None for s in sims)
    assert all(s.num_generated == s.max_new_tokens for s in sims)
    assert all(s.replica == 0 for s in sims)


def test_des_rejects_pd_pool():
    with pytest.raises(ValueError):
        DiscreteEventSimulator(
            StaticPredictor(DT), DESConfig(),
            num_replicas=2, router=make_router("pd_pool", 2))


def test_des_rejects_router_size_mismatch():
    with pytest.raises(ValueError):
        DiscreteEventSimulator(
            StaticPredictor(DT), DESConfig(),
            num_replicas=2, router=make_router("round_robin", 4))


# =========================================================================
# benchmark pipeline over a cluster
# =========================================================================

def test_benchmark_runner_drives_cluster():
    reqs = workload(n=12, qps=40.0)
    cluster = build_cluster(MODEL, engine_cfg(), 2, policy="round_robin",
                            predictor=StaticPredictor(DT))
    try:
        res = BenchmarkRunner(cluster, reqs,
                              transport=cluster.transport).run(timeout=120)
    finally:
        cluster.shutdown()
    assert res.num_requests == 12
    assert res.num_replicas == 2
    assert res.routing_policy == "round_robin"
    assert len(res.per_replica) == 2
    assert res.ttft.p50 > 0 and res.makespan_virtual > 0
    assert res.goodput_rps() == pytest.approx(res.request_rate_completed)
    assert res.goodput_rps(slo_ttft_s=0.0) == 0.0
    assert "completed_rps" in res.summary()
    # observer surface: first poll drains everything, second is empty
    assert len(cluster.poll()) == 12
    assert cluster.poll() == []
