"""O(1)-memory metrics: GK sketch guarantees, reservoir determinism, and the
LatencyStats exact/sketch-backed contract.

The streaming scale path (``audit="sampled"``) replaces retained per-request
lists with these accumulators, so the properties under test here — exact
small-N equivalence with numpy, the ±eps·n rank-error bound past the cap,
deterministic serialized state — are what keep million-session results
trustworthy and reproducible."""

from __future__ import annotations

import json

import numpy as np
import pytest

from repro.metrics import (LatencyAccumulator, LatencyStats, QuantileSketch,
                           ReservoirSample, StreamingStat,
                           compare_distributions)

QS = (1.0, 5.0, 25.0, 50.0, 75.0, 90.0, 95.0, 99.0)


def _rank_err(sorted_vals: np.ndarray, answer: float, q: float) -> float:
    """Absolute rank distance between the sketch's answer and the target."""
    target = (q / 100.0) * (len(sorted_vals) - 1)
    lo = np.searchsorted(sorted_vals, answer, side="left")
    hi = np.searchsorted(sorted_vals, answer, side="right")
    # the answer occupies a rank interval when duplicated; take the closest
    if lo <= target <= hi:
        return 0.0
    return float(min(abs(lo - target), abs(hi - target)))


# ---------------------------------------------------------------- exact mode

def test_exact_small_n_is_bit_identical_to_numpy():
    rng = np.random.default_rng(7)
    vals = rng.lognormal(0.0, 1.0, size=500)      # below exact_cap
    sk = QuantileSketch()
    sk.extend(vals)
    for q in QS:
        assert sk.percentile(q) == float(np.percentile(vals, q))
    assert sk.mean == pytest.approx(float(vals.mean()))
    assert sk.maximum == float(vals.max())


def test_exact_mode_single_value_and_empty():
    sk = QuantileSketch()
    with pytest.raises(ValueError):
        sk.quantile(0.5)
    sk.add(3.25)
    assert sk.percentile(50) == 3.25 == sk.percentile(99)


# ------------------------------------------------------------ GK rank error

@pytest.mark.parametrize("order", ["random", "ascending", "descending"])
def test_gk_rank_error_bound(order):
    n, eps = 50_000, 0.01
    rng = np.random.default_rng(11)
    vals = rng.gamma(2.0, 0.5, size=n)
    if order == "ascending":
        vals = np.sort(vals)                       # adversarial: sorted feed
    elif order == "descending":
        vals = np.sort(vals)[::-1]
    sk = QuantileSketch(eps=eps, exact_cap=256)
    sk.extend(vals)
    srt = np.sort(vals)
    for q in QS:
        err = _rank_err(srt, sk.percentile(q), q)
        assert err <= 2 * eps * n, (
            f"p{q} rank error {err} exceeds 2*eps*n={2 * eps * n} "
            f"({order} insertion)")
    # footprint is the point: summary stays tiny relative to the stream
    assert sk.num_entries < 4_000


def test_gk_min_max_stay_exact():
    rng = np.random.default_rng(3)
    vals = rng.normal(size=20_000)
    sk = QuantileSketch(eps=0.02, exact_cap=64)
    sk.extend(vals)
    assert sk.percentile(0) == float(np.min(vals))
    assert sk.percentile(100) == float(np.max(vals))


# ------------------------------------------------------------------- merge

def test_merge_matches_single_stream_within_bound():
    n, eps = 30_000, 0.01
    rng = np.random.default_rng(23)
    vals = rng.exponential(1.0, size=n)
    chunks = np.array_split(vals, 3)
    sks = []
    for c in chunks:
        sk = QuantileSketch(eps=eps, exact_cap=128)
        sk.extend(c)
        sks.append(sk)
    left = sks[0].merge(sks[1]).merge(sks[2])      # (a ⊕ b) ⊕ c
    right = sks[0].merge(sks[1].merge(sks[2]))     # a ⊕ (b ⊕ c)
    srt = np.sort(vals)
    for m in (left, right):
        assert m.count == n
        assert m.maximum == float(vals.max())
        for q in QS:
            # merged error is the sum of the inputs' errors: 3 streams
            assert _rank_err(srt, m.percentile(q), q) <= 4 * eps * n
    # both association orders agree within the same bound
    for q in QS:
        assert abs(_rank_err(srt, left.percentile(q), q)
                   - _rank_err(srt, right.percentile(q), q)) <= 4 * eps * n


def test_merge_of_small_exact_sketches_stays_exact():
    a, b = QuantileSketch(), QuantileSketch()
    a.extend([1.0, 3.0, 5.0])
    b.extend([2.0, 4.0])
    m = a.merge(b)
    vals = np.array([1.0, 2.0, 3.0, 4.0, 5.0])
    for q in QS:
        assert m.percentile(q) == float(np.percentile(vals, q))


# ------------------------------------------------------------- determinism

def test_sketch_state_is_byte_stable():
    def build():
        sk = QuantileSketch(eps=0.02, exact_cap=32)
        rng = np.random.default_rng(5)
        sk.extend(rng.uniform(0, 10, size=5_000))
        return sk
    s1 = json.dumps(build().state(), sort_keys=True)
    s2 = json.dumps(build().state(), sort_keys=True)
    assert s1 == s2
    assert s1.encode() == s2.encode()              # the byte-level contract


def test_reservoir_is_seed_deterministic():
    def build(seed):
        r = ReservoirSample(capacity=64, seed=seed)
        for i in range(10_000):
            r.add(i)
        return r
    a, b = build(0), build(0)
    assert a.items == b.items
    assert len(a) == 64 and a.count == 10_000 and not a.exact
    assert build(1).items != a.items               # seed actually matters
    small = ReservoirSample(capacity=8, seed=0)
    for i in range(5):
        small.add(i)
    assert small.exact and small.items == [0, 1, 2, 3, 4]


def test_streaming_stat():
    s = StreamingStat()
    for v in (2.0, -1.0, 4.5):
        s.add(v)
    assert (s.count, s.minimum, s.maximum) == (3, -1.0, 4.5)
    assert s.mean == pytest.approx(5.5 / 3)


# ----------------------------------------------------- LatencyStats surface

def test_latency_stats_drops_raw_by_default():
    vals = list(np.random.default_rng(9).lognormal(size=300))
    st = LatencyStats.of(vals)
    assert st.values == [] and st.count == 300
    assert st.sketch is not None
    # arbitrary percentile answered from the sketch, exact at this size
    assert st.percentile(75) == float(np.percentile(vals, 75))
    kept = LatencyStats.of(vals, keep_raw=True)
    assert kept.values == [float(v) for v in vals]
    assert kept.p99 == st.p99


def test_latency_accumulator_matches_of_small_n():
    vals = list(np.random.default_rng(13).uniform(0, 1, size=400))
    acc = LatencyAccumulator()
    for v in vals:
        acc.add(v)
    a, b = acc.stats(), LatencyStats.of(vals)
    assert (a.p50, a.p90, a.p99) == (b.p50, b.p90, b.p99)
    assert a.count == b.count == 400
    assert a.mean == pytest.approx(b.mean)


def test_compare_distributions_on_sketch_backed_stats():
    rng = np.random.default_rng(17)
    base = rng.lognormal(0.0, 0.5, size=5_000)
    a = LatencyStats.of(base)
    b = LatencyStats.of(base * 1.02)               # 2% uniform shift
    d = compare_distributions(a, b)
    for k in ("p50_rel_err", "p90_rel_err", "p99_rel_err",
              "median_rel_err"):
        assert 0.0 <= d[k] <= 0.1
    same = compare_distributions(a, a)
    assert same["median_rel_err"] == 0.0


def test_compare_distributions_rejects_empty_side():
    full = LatencyStats.of([1.0, 2.0, 3.0])
    with pytest.raises(ValueError, match="has no samples"):
        compare_distributions(full, LatencyStats.of([]))
    with pytest.raises(ValueError, match="has no samples"):
        compare_distributions(LatencyStats(0.0, 0.0, 0.0, 0.0), full)
