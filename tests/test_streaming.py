"""The million-session streaming path: lazy workloads, sink-fed DES,
audit modes, and the flat-memory guarantee.

Three invariants carry the scale story and are pinned here:

1. **Laziness is invisible** — a lazily-consumed stream produces the exact
   results of materializing it first (DES identity test), arrival
   generators yield the same times their batch ``sample`` draws, and the
   streaming workloads are re-iterable and byte-stable.
2. **Sampling is honest** — ``audit="sampled"`` reproduces full-audit
   headline numbers (counts, percentiles) while dropping per-request
   retention; ``audit="off"`` additionally drops the SLO reservoir.
3. **Memory is flat** — 10× the sessions must cost <= 1.5× the traced
   peak (the tracemalloc regression gate for the whole sink path).
"""

from __future__ import annotations

import gc
import tracemalloc
import types

import numpy as np
import pytest

from repro.core.predictor import StaticPredictor
from repro.des.simulator import DESConfig, DiscreteEventSimulator
from repro.scenario import compare, get_preset, run, scenario_with
from repro.scenario.__main__ import main as scenario_cli
from repro.serving.benchmark import BenchmarkRunner
from repro.workload import (SessionConfig, StreamingSessionWorkload,
                            StreamingWorkload, WorkloadConfig)
from repro.workload.arrival import ARRIVAL_PROCESSES, make_arrival

ARRIVAL_KWARGS = {
    "trace": {"trace": [[5.0, 2.0], [5.0, 6.0], [5.0, 1.0]]},
}


def _tiny(n=40, **over):
    return scenario_with(get_preset("scale_stream"),
                         workload__num_sessions=n, **over)


# ------------------------------------------------------------ arrival lazy

@pytest.mark.parametrize("name", sorted(ARRIVAL_PROCESSES))
def test_iter_times_matches_batch_sample(name):
    """The lazy generator is the batch draw: same rng seed, same times."""
    proc = make_arrival(name, 4.0, **ARRIVAL_KWARGS.get(name, {}))
    n = 600                                        # spans several chunks
    batch = proc.sample(n, np.random.default_rng(21))
    it = proc.iter_times(np.random.default_rng(21), chunk=256)
    lazy = np.array([next(it) for _ in range(n)])
    assert np.array_equal(batch, lazy), (
        f"{name}: iter_times diverges from sample")


# --------------------------------------------------------- lazy workloads

def test_streaming_workload_reiterable_and_chunk_invariant():
    cfg = WorkloadConfig(num_requests=700, qps=8.0, seed=11)
    sw = StreamingWorkload(cfg, chunk=256)
    def fingerprint(w):
        return [(r.arrival_time, tuple(r.prompt_tokens), r.max_new_tokens)
                for r in w]
    a = fingerprint(sw)
    assert len(a) == sw.expected == 700
    assert a == fingerprint(sw)                    # re-iterable
    assert a == fingerprint(StreamingWorkload(cfg, chunk=7))  # chunk-free
    times = [t for t, _, _ in a]
    assert times == sorted(times)


def test_streaming_sessions_shape_pass_and_eviction():
    cfg = SessionConfig(num_sessions=50, qps=20.0, seed=5,
                        turns_mean=2.0, max_turns=3)
    ssw = StreamingSessionWorkload(cfg)
    assert ssw.expected == sum(ssw.session_turns(s) for s in range(50))
    first = list(ssw.initial_stream())
    assert [r.prompt_tokens for r in first] == \
        [r.prompt_tokens for r in ssw.initial_stream()]      # re-iterable
    assert all(r.turn_index == 0 for r in first)

    # drive one session through its turns by hand: follow_up materializes
    # lazily and evicts on the last turn
    multi = next(r for r in first if ssw.session_turns(r.session_id) > 1)
    sid, turn, t = multi.session_id, 0, multi.arrival_time
    while True:
        done = types.SimpleNamespace(session_id=sid, turn_index=turn,
                                     finish_time=t + 0.5)
        nxt = ssw.follow_up(done)
        if nxt is None:
            break
        assert nxt.session_id == sid and nxt.turn_index == turn + 1
        assert nxt.arrival_time > done.finish_time  # think time elapsed
        turn, t = nxt.turn_index, nxt.arrival_time
    assert turn == ssw.session_turns(sid) - 1
    assert sid not in ssw._live                    # evicted when done


# --------------------------------------------------- declared-count errors

def test_benchmark_runner_rejects_bare_generator():
    gen = (r for r in [])
    with pytest.raises(ValueError, match=r"expected=N"):
        BenchmarkRunner(types.SimpleNamespace(), gen)


# ------------------------------------------------------------ DES identity

def _des(record_decisions=True):
    from repro.cluster.router import make_router
    router = make_router("round_robin", 2)
    router.record_decisions = record_decisions
    return DiscreteEventSimulator(
        StaticPredictor(5e-3),
        DESConfig(max_num_seqs=8, max_batched_tokens=512,
                  step_overhead_s=0.0),
        num_replicas=2, router=router)


def test_des_lazy_stream_is_identical_to_materialized():
    """Feeding the DES lazily must replay the eager event order exactly."""
    sw = StreamingWorkload(WorkloadConfig(num_requests=120, qps=40.0,
                                          seed=7, output_len_mean=8.0,
                                          max_output_len=16))
    eager = _des().run(sorted(sw, key=lambda r: r.arrival_time))
    lazy = _des().run(sw)
    assert len(eager) == len(lazy) == 120
    for a, b in zip(eager, lazy):
        assert (a.arrival_time, a.first_token_time, a.finish_time,
                a.replica) == \
               (b.arrival_time, b.first_token_time, b.finish_time, b.replica)


def test_des_sink_mode_retains_nothing():
    sw = StreamingWorkload(WorkloadConfig(num_requests=150, qps=40.0,
                                          seed=7, output_len_mean=8.0,
                                          max_output_len=16))
    seen = []
    out = _des().run(sw, sink=seen.append)
    assert out == []                               # nothing retained
    assert len(seen) == 150
    assert all(s.finish_time is not None for s in seen)


def test_des_decreasing_stream_rejected():
    bad = StreamingWorkload(WorkloadConfig(num_requests=3, qps=4.0, seed=1))
    reqs = list(bad)
    reqs[2].arrival_time = 0.0                     # violate monotonicity
    stream = iter(reqs)
    with pytest.raises(ValueError, match="non-decreasing"):
        _des().run(stream)


# ------------------------------------------------------------- audit modes

def test_audit_sampled_matches_full_on_thread():
    full = run(_tiny(), backend="thread", audit="full")
    sam = run(_tiny(), backend="thread", audit="sampled")
    assert (full.audit, sam.audit) == ("full", "sampled")
    assert sam.num_requests == full.num_requests
    assert sam.num_sessions == full.num_sessions
    for metric in ("ttft", "tpot", "e2e"):
        a, b = getattr(full, metric), getattr(sam, metric)
        assert a.count == b.count
        assert a.p50 == pytest.approx(b.p50, abs=1e-9)
        assert a.p99 == pytest.approx(b.p99, abs=1e-9)
    # sampled drops retention but keeps counter-backed accounting
    assert sam.latencies == {} and not sam.placements
    assert sam.num_slo_samples == sam.num_requests
    assert sam.slo_attainment() == pytest.approx(full.slo_attainment())

    off = run(_tiny(), backend="des", audit="off")
    assert off.slo_samples == [] and off.num_requests == full.num_requests


def test_streaming_thread_des_parity():
    cres = compare(_tiny(), backends=("thread", "des"))
    assert cres.to_row()["max_err_steps"] <= 1.0


# ------------------------------------------------------------- flat memory

def test_streaming_memory_flat_10x_requests():
    """10× the requests must cost <= 1.5× the traced allocation peak.

    Uses tight accumulator bounds (small reservoir / exact_cap) so every
    O(1) structure saturates well below the small run's size — past that
    point the whole replay path (lazy workload → DES → sink → sketches)
    must hold nothing per-request."""
    from repro.metrics import StreamingMetrics

    def peak(n):
        sw = StreamingWorkload(WorkloadConfig(
            num_requests=n, qps=40.0, seed=7,
            output_len_mean=8.0, max_output_len=16))
        # coarse eps: the GK summary is O(1/eps · log(eps·n)), so a tight
        # eps at tiny n measures the sketch's log growth, not retention
        m = StreamingMetrics(slo_reservoir=256, eps=0.05, exact_cap=128)
        gc.collect()
        tracemalloc.start()
        # record_decisions off, as the runner's sampled path sets it: the
        # routing log is per-request state
        _des(record_decisions=False).run(sw, sink=m.observe)
        _, pk = tracemalloc.get_traced_memory()
        tracemalloc.stop()
        m.finalize()
        assert m.count == n
        return pk
    peak(1_500)                                    # warm caches off-measure
    small, big = peak(1_500), peak(15_000)
    assert big <= 1.5 * small, (
        f"streaming DES peak grew {big / small:.2f}x for 10x requests "
        f"({small} -> {big} bytes): something retains per-request state")


# --------------------------------------------------------------------- CLI

def test_cli_run_streaming_sampled(capsys):
    rc = scenario_cli(["run", "scale_stream", "--sessions", "30",
                       "--backend", "des", "--audit", "sampled"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "scale_stream" in out
