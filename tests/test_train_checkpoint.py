"""Training fault tolerance: checkpoint/restart must be bit-deterministic —
train N steps straight == train k, fail, restore, train N-k (same data
stream, same optimizer state, same params).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.launch.train import data_stream
from repro.models.checkpoint import (latest_step, restore_checkpoint,
                                     save_checkpoint)
from repro.models.optim import OptimizerConfig, init_adamw, make_train_step
from repro.models.transformer import build_model


def make(arch="olmo_1b"):
    cfg = get_reduced_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0), jnp.float32)
    opt = init_adamw(params)
    step_fn = jax.jit(make_train_step(
        model, OptimizerConfig(warmup_steps=2, total_steps=10),
        microbatches=1, remat=False))
    return cfg, params, opt, step_fn


def run(cfg, params, opt, step_fn, start, stop):
    stream = data_stream(cfg.vocab_size, 2, 16, seed=7, start_step=start)
    for _ in range(start, stop):
        params, opt, metrics = step_fn(params, opt, next(stream))
    return params, opt, metrics


def test_checkpoint_restart_is_bit_deterministic(tmp_path):
    cfg, params0, opt0, step_fn = make()

    # straight-through reference: 4 steps
    p_ref, o_ref, m_ref = run(cfg, params0, opt0, step_fn, 0, 4)

    # 2 steps -> checkpoint -> "crash" -> restore -> 2 more steps
    p_half, o_half, _ = run(cfg, params0, opt0, step_fn, 0, 2)
    save_checkpoint(tmp_path, 2, p_half, o_half, extra={"loss": 1.0})
    assert latest_step(tmp_path) == 2
    p_rest, o_rest, meta = restore_checkpoint(tmp_path, 2, params0, opt0)
    assert meta["step"] == 2
    p_out, o_out, m_out = run(cfg, p_rest, o_rest, step_fn, 2, 4)

    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(o_out.step) == int(o_ref.step) == 4
    assert float(m_out["loss"]) == pytest.approx(float(m_ref["loss"]),
                                                 rel=1e-6)


def test_latest_step_picks_newest(tmp_path):
    cfg, params, opt, _ = make()
    for s in (1, 3, 2):
        save_checkpoint(tmp_path, s, params, opt)
    assert latest_step(tmp_path) == 3


def test_restore_validates_shapes(tmp_path):
    cfg, params, opt, _ = make()
    save_checkpoint(tmp_path, 1, params, opt)
    other = build_model(get_reduced_config("olmo_1b").replace(d_model=32,
                                                              head_dim=8))
    bad_params = other.init(jax.random.key(0), jnp.float32)
    with pytest.raises(AssertionError):
        restore_checkpoint(tmp_path, 1, bad_params, init_adamw(bad_params))
