"""Runtime predictors: analytical model sanity, table fitting, collectives."""

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                              # optional dev dependency
    from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core.hardware import H100, TPU_V5E
from repro.core.predictor import (AnalyticalPredictor, BatchSpec,
                                  ParallelSpec, SeqSpec, StaticPredictor,
                                  TablePredictor, collective_time)


def batch(*seqs):
    return BatchSpec.make([SeqSpec(*s) for s in seqs])


def test_static():
    p = StaticPredictor(0.02)
    assert p.predict_step(batch((1, 100))).total == 0.02


def test_collective_time_formulas():
    # all-reduce = 2(n-1)/n * B / bw_eff
    t = collective_time(1e9, 4, TPU_V5E, "all_reduce")
    bw = TPU_V5E.interconnect_bandwidth * TPU_V5E.collective_efficiency
    assert t == pytest.approx(2 * 0.75 * 1e9 / bw)
    assert collective_time(1e9, 1, TPU_V5E) == 0.0
    assert collective_time(1e9, 4, TPU_V5E, "all_gather") == pytest.approx(t / 2)


def test_analytical_decode_memory_bound():
    cfg = get_config("llama3_8b")
    pred = AnalyticalPredictor(cfg, ParallelSpec(tp=1), TPU_V5E)
    est = pred.predict_step(batch((1, 2048)))
    # single-token decode on an 8B model is overwhelmingly memory-bound
    assert est.memory > 5 * est.compute
    # weight streaming floor: params * 2B / (bw*eff)
    floor = cfg.param_count() * 2 / (TPU_V5E.hbm_bandwidth * TPU_V5E.hbm_efficiency)
    assert est.total >= 0.8 * floor


def test_analytical_prefill_compute_bound():
    cfg = get_config("llama3_8b")
    pred = AnalyticalPredictor(cfg, ParallelSpec(tp=1), H100)
    est = pred.predict_step(batch((4096, 4096)))
    assert est.compute > est.memory


def test_analytical_monotonicity():
    cfg = get_config("qwen2_5_3b")
    pred = AnalyticalPredictor(cfg, ParallelSpec(tp=1), TPU_V5E)
    t1 = pred.predict_step(batch((1, 512))).total
    t2 = pred.predict_step(batch((1, 512), (1, 512))).total
    t3 = pred.predict_step(batch((256, 512))).total
    assert t2 >= t1
    assert t3 > t1


def test_tp_reduces_time_adds_collectives():
    cfg = get_config("llama3_70b")
    t1 = AnalyticalPredictor(cfg, ParallelSpec(tp=1), TPU_V5E).predict_step(
        batch((512, 512)))
    t4 = AnalyticalPredictor(cfg, ParallelSpec(tp=4), TPU_V5E).predict_step(
        batch((512, 512)))
    assert t4.total < t1.total
    assert t4.collective_bytes > 0
    assert t1.collective_bytes == 0


def test_moe_cheaper_than_dense_equivalent():
    """MoE top-2/8 should cost ~active params, not total params."""
    moe = get_config("mixtral_8x7b")
    pred = AnalyticalPredictor(moe, ParallelSpec(tp=1), H100)
    est = pred.predict_step(batch((2048, 2048)))
    # compute should track 6*N_active, far below 6*N_total
    dense_flops_all = 2 * moe.param_count() * 2048
    assert est.flops < 0.6 * dense_flops_all


def test_sliding_window_caps_decode_cost():
    cfg = get_config("mixtral_8x7b")          # SWA 4096
    pred = AnalyticalPredictor(cfg, ParallelSpec(tp=1), H100)
    near = pred.predict_step(batch((1, 4096))).total
    far = pred.predict_step(batch((1, 500_000))).total
    assert far <= near * 1.05                 # window bounds KV reads


def test_table_predictor_fit_and_interp():
    tp = TablePredictor()
    tp.fit([
        (batch((512, 512)), 0.020),
        (batch((1, 600), (1, 600)), 0.004),
        (batch((256, 256)), 0.011),
    ])
    est = tp.predict_step(batch((512, 512)))
    assert est.total == pytest.approx(0.020, rel=0.15)
    with pytest.raises(RuntimeError):
        TablePredictor().predict_step(batch((1, 1)))


@settings(max_examples=30, deadline=None)
@given(
    n_decode=st.integers(0, 64),
    ctx=st.integers(16, 8192),
    chunk=st.integers(0, 2048),
)
def test_property_estimates_positive_and_bounded(n_decode, ctx, chunk):
    cfg = get_config("qwen2_5_3b")
    pred = AnalyticalPredictor(cfg, ParallelSpec(tp=1), TPU_V5E)
    seqs = [(1, ctx)] * n_decode + ([(chunk, chunk)] if chunk else [])
    if not seqs:
        return
    est = pred.predict_step(BatchSpec.make([SeqSpec(*s) for s in seqs]))
    assert est.total > 0
    assert est.total < 60.0            # nothing takes a virtual minute
    assert est.flops > 0
