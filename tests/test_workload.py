"""Workload subsystem tests: arrival processes, the first-gap regression,
byte-stability of the refactor, and session (multi-turn) synthesis."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                              # optional dev dependency
    from _hypothesis_compat import given, settings, st

from repro.workload import (ARRIVAL_PROCESSES, GammaArrivals, OnOffArrivals,
                            PoissonArrivals, RateTraceArrivals, SessionConfig,
                            SessionWorkload, WorkloadConfig, make_arrival,
                            synthesize)
from repro.workload.session import _DUMMY


# =========================================================================
# arrival processes
# =========================================================================

def test_registry_and_make():
    assert set(ARRIVAL_PROCESSES) == {"uniform", "poisson", "gamma", "onoff",
                                      "trace"}
    assert isinstance(make_arrival("gamma", 2.0, cv2=8.0), GammaArrivals)
    with pytest.raises(ValueError):
        make_arrival("nope", 2.0)


@pytest.mark.parametrize("proc", [
    make_arrival("uniform", 5.0),
    PoissonArrivals(5.0),
    GammaArrivals(5.0, cv2=8.0),
    OnOffArrivals(5.0, period_s=4.0, duty=0.25),
    RateTraceArrivals([(5.0, 2.0), (5.0, 8.0)], scale_to_qps=5.0),
])
def test_arrival_streams_sorted_and_rate_correct(proc):
    ts = proc.sample(2000, np.random.default_rng(11))
    if proc.name != "trace":            # trace replay keeps absolute phase
        assert ts[0] == 0.0
    assert (np.diff(ts) >= 0).all()
    empirical = (len(ts) - 1) / (ts[-1] - ts[0])
    assert empirical == pytest.approx(proc.mean_rate(), rel=0.15), \
        f"{proc.name}: rate {empirical:.2f} vs declared {proc.mean_rate()}"


def test_gamma_burstiness_overdispersion():
    """cv2 controls inter-arrival dispersion: the bursty stream's gap CV^2
    must be far above Poisson's ~1 at the same mean rate."""
    rng = np.random.default_rng(3)
    gaps_p = np.diff(PoissonArrivals(4.0).sample(4000, rng))
    gaps_g = np.diff(GammaArrivals(4.0, cv2=10.0).sample(
        4000, np.random.default_rng(3)))
    cv2 = lambda g: g.var() / g.mean() ** 2
    assert 0.7 < cv2(gaps_p) < 1.4
    assert cv2(gaps_g) > 4.0
    assert gaps_p.mean() == pytest.approx(gaps_g.mean(), rel=0.25)


def test_onoff_has_silent_phases():
    ts = OnOffArrivals(10.0, period_s=2.0, duty=0.25).sample(
        400, np.random.default_rng(5))
    gaps = np.diff(ts)
    # OFF phases appear as gaps >= the 1.5s silence; ON-phase gaps are small
    assert (gaps >= 1.4).sum() >= 3, "no off-phase silences in the stream"
    assert np.median(gaps) < 0.2, "on-phase arrivals should be dense"


def test_rate_trace_follows_diurnal_shape():
    """Arrivals must concentrate in the high-rate segments of the trace."""
    trace = [(10.0, 1.0), (10.0, 9.0)]          # quiet phase, busy phase
    ts = RateTraceArrivals(trace).sample(1000, np.random.default_rng(9))
    period = ts % 20.0
    busy = ((period >= 10.0) & (period < 20.0)).mean()
    assert busy > 0.75, f"only {busy:.0%} of arrivals in the busy phase"


# =========================================================================
# first-gap regression (satellite fix) + byte stability
# =========================================================================

def test_first_gap_not_clobbered():
    """The historical bug set arrivals[0]=0 on the cumulative sum, silently
    merging gaps[0] into the second arrival's offset and biasing effective
    QPS for small n.  The stream must instead be *shifted*: request 0 at
    t=0 and every inter-arrival gap equal to the generator's draws."""
    cfg = WorkloadConfig(num_requests=50, qps=4.0, seed=123)
    reqs = synthesize(cfg)
    arrivals = np.array([r.arrival_time for r in reqs])
    # reference: the raw exponential draws of the same seeded generator
    rng = np.random.default_rng(123)
    gaps = rng.exponential(1.0 / 4.0, size=50)
    assert arrivals[0] == 0.0
    np.testing.assert_allclose(np.diff(arrivals), gaps[1:], rtol=0, atol=1e-12)
    # the old behaviour inflated the first gap to gaps[0]+gaps[1]
    assert arrivals[1] == pytest.approx(gaps[1], abs=1e-12)


def test_synthesize_byte_stable_lengths_and_tokens():
    """The package refactor + arrival fix must not perturb the non-arrival
    draws: prompt/output lengths and token bodies stay byte-identical to the
    historical single-process implementation (same seeded draw order)."""
    cfg = WorkloadConfig(num_requests=20, qps=3.0, seed=42,
                         shared_prefix_len=8, prompt_len_mean=50,
                         output_len_mean=20)
    reqs = synthesize(cfg)

    # independent reference replay of the historical draw order
    rng = np.random.default_rng(42)
    _ = rng.exponential(1.0 / 3.0, size=20)        # arrival gaps
    def lens(mean, sigma, lo, hi):
        mu = np.log(mean) - sigma**2 / 2
        return np.clip(rng.lognormal(mu, sigma, size=20).astype(int), lo, hi)
    plens = lens(50, 0.6, cfg.min_prompt_len, cfg.max_prompt_len)
    olens = lens(20, 0.6, cfg.min_output_len, cfg.max_output_len)
    shared = rng.integers(1, cfg.vocab_size, size=8).tolist()
    for i, r in enumerate(reqs):
        body_len = max(int(plens[i]) - 8, 1)
        body = rng.integers(1, cfg.vocab_size, size=body_len).tolist()
        assert list(r.prompt_tokens) == shared + body
        assert r.max_new_tokens == int(olens[i])


def test_synthesize_deterministic_across_calls():
    a = synthesize(WorkloadConfig(num_requests=12, qps=5.0, seed=7))
    b = synthesize(WorkloadConfig(num_requests=12, qps=5.0, seed=7))
    for x, y in zip(a, b):
        assert list(x.prompt_tokens) == list(y.prompt_tokens)
        assert x.arrival_time == y.arrival_time
        assert x.max_new_tokens == y.max_new_tokens


def test_bursty_workload_through_config():
    reqs = synthesize(WorkloadConfig(num_requests=200, qps=4.0, seed=1,
                                     arrival="gamma",
                                     arrival_kwargs={"cv2": 9.0}))
    gaps = np.diff([r.arrival_time for r in reqs])
    assert gaps.var() / gaps.mean() ** 2 > 3.0


# =========================================================================
# sessions
# =========================================================================

def _session_cfg(**kw):
    base = dict(num_sessions=6, qps=2.0, turns_mean=3.0, max_turns=5,
                think_time_mean=1.0, prompt_len_mean=40, followup_len_mean=12,
                output_len_mean=8, max_output_len=16, seed=17)
    base.update(kw)
    return SessionConfig(**base)


def test_session_prompts_chain_prior_turns():
    """Turn k+1's prompt must literally extend turn k's prompt + its dummy
    outputs — that token-level chaining is what produces real radix-cache
    reuse (not a synthetic shared prefix)."""
    sw = SessionWorkload(_session_cfg())
    multi = [s for s in sw.sessions if s.num_turns >= 2]
    assert multi, "turns_mean=3 must yield multi-turn sessions"
    for s in multi:
        for k in range(1, s.num_turns):
            prev, cur = s.turns[k - 1], s.turns[k]
            expected_head = (list(prev.prompt_tokens)
                             + [_DUMMY] * prev.max_new_tokens)
            assert list(cur.prompt_tokens[:len(expected_head)]) == expected_head
            assert len(cur.prompt_tokens) > len(expected_head)
            assert cur.think_time > 0.0
        assert s.turns[0].think_time == 0.0


def test_session_follow_up_rule():
    sw = SessionWorkload(_session_cfg())
    init = sw.initial_requests()
    assert len(init) == sw.num_sessions
    assert sum(s.num_turns for s in sw.sessions) == sw.total_requests
    first = next(r for r in init
                 if sw.sessions[r.session_id].num_turns >= 2)
    first.finish_time = first.arrival_time + 2.5
    fu = sw.follow_up(first)
    assert fu.session_id == first.session_id and fu.turn_index == 1
    spec = sw.sessions[first.session_id].turns[1]
    assert fu.arrival_time == pytest.approx(
        first.finish_time + spec.think_time)
    # last turn yields no follow-up
    last_turn = sw.sessions[first.session_id].num_turns - 1
    tail = sw._request(sw.sessions[first.session_id], last_turn, 0.0)
    tail.finish_time = 1.0
    assert sw.follow_up(tail) is None
    # open-loop requests (no session identity) never re-inject
    class _NoSession:
        session_id = None
    assert sw.follow_up(_NoSession()) is None


def test_session_workload_reusable_across_runs():
    """initial_requests/follow_up must build fresh Request objects so one
    workload can drive an emulator run and a DES run back to back."""
    sw = SessionWorkload(_session_cfg())
    a, b = sw.initial_requests(), sw.initial_requests()
    assert [list(r.prompt_tokens) for r in a] == \
           [list(r.prompt_tokens) for r in b]
    assert all(x is not y for x, y in zip(a, b))
    a[0].num_prefilled = 999            # mutating one run's objects...
    assert sw.initial_requests()[0].num_prefilled == 0   # ...leaks nowhere


def test_session_context_cap_ends_sessions_early():
    sw = SessionWorkload(_session_cfg(max_context_len=64, max_turns=8,
                                      output_len_mean=30, max_output_len=40))
    for s in sw.sessions:
        for t in s.turns:
            assert len(t.prompt_tokens) <= 64


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**16))
def test_session_synthesis_deterministic(seed):
    a = SessionWorkload(_session_cfg(seed=seed))
    b = SessionWorkload(_session_cfg(seed=seed))
    assert a.total_requests == b.total_requests
    for sa, sb in zip(a.sessions, b.sessions):
        assert sa.arrival_time == sb.arrival_time
        for ta, tb in zip(sa.turns, sb.turns):
            assert ta.prompt_tokens == tb.prompt_tokens
            assert ta.max_new_tokens == tb.max_new_tokens
            assert ta.think_time == tb.think_time


# =========================================================================
# compat shim removal
# =========================================================================

def test_serving_workload_shim_is_gone():
    """The deprecated ``repro.serving.workload`` shim was removed after its
    deprecation cycle; the canonical surface lives in ``repro.workload``."""
    import importlib

    with pytest.raises(ModuleNotFoundError):
        importlib.import_module("repro.serving.workload")
