"""Pallas kernel validation (assignment requirement): sweep shapes/dtypes and
assert_allclose each kernel (interpret=True on CPU) against its ref.py oracle.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                              # optional dev dependency
    from _hypothesis_compat import given, settings, st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.paged_attention import paged_attention
from repro.kernels.ssd_scan import ssd_scan

TOL = dict(rtol=2e-2, atol=2e-2)      # bf16 inputs, fp32 accumulation
TOL32 = dict(rtol=2e-5, atol=2e-5)


def _qkv(key, B, T, S, Hq, Hkv, D, dtype):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, T, Hq, D), jnp.float32).astype(dtype)
    k = jax.random.normal(kk, (B, S, Hkv, D), jnp.float32).astype(dtype)
    v = jax.random.normal(kv, (B, S, Hkv, D), jnp.float32).astype(dtype)
    return q, k, v


# =========================================================================
# flash attention
# =========================================================================

@pytest.mark.parametrize("B,T,S,Hq,Hkv,D", [
    (1, 128, 128, 4, 4, 64),        # MHA square
    (2, 128, 256, 8, 2, 64),        # GQA, chunked prefill (q = last T of S)
    (1, 64, 64, 4, 1, 128),         # MQA, D=128
    (1, 100, 100, 2, 2, 64),        # non-multiple-of-block T
    (1, 32, 160, 4, 4, 32),         # small D, long KV
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_vs_ref(B, T, S, Hq, Hkv, D, dtype):
    q, k, v = _qkv(jax.random.key(0), B, T, S, Hq, Hkv, D, dtype)
    out = flash_attention(q, k, v, causal=True, interpret=True)
    exp = ref.flash_attention_ref(q, k, v, causal=True)
    tol = TOL if dtype == jnp.bfloat16 else dict(rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **tol)


@pytest.mark.parametrize("window", [16, 64, 4096])
def test_flash_sliding_window(window):
    q, k, v = _qkv(jax.random.key(1), 1, 128, 128, 4, 2, 64, jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window, interpret=True)
    exp = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-4, atol=2e-4)


def test_flash_non_causal():
    q, k, v = _qkv(jax.random.key(2), 2, 64, 64, 4, 4, 64, jnp.float32)
    out = flash_attention(q, k, v, causal=False, interpret=True)
    exp = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-4, atol=2e-4)


def test_flash_custom_scale():
    q, k, v = _qkv(jax.random.key(3), 1, 64, 64, 2, 2, 64, jnp.float32)
    out = flash_attention(q, k, v, softmax_scale=0.5, interpret=True)
    exp = ref.flash_attention_ref(q, k, v, softmax_scale=0.5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=12, deadline=None)
@given(
    T=st.sampled_from([8, 33, 64, 127]),
    extra=st.sampled_from([0, 16, 93]),
    Hkv=st.sampled_from([1, 2]),
    G=st.sampled_from([1, 2, 4]),
    D=st.sampled_from([32, 64]),
)
def test_flash_property_sweep(T, extra, Hkv, G, D):
    """Property sweep: arbitrary (T, S≥T, GQA group, D) agree with oracle."""
    S = T + extra
    q, k, v = _qkv(jax.random.key(42), 1, T, S, Hkv * G, Hkv, D, jnp.float32)
    out = flash_attention(q, k, v, causal=True, interpret=True)
    exp = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=5e-4, atol=5e-4)


# =========================================================================
# paged attention
# =========================================================================

def _paged_inputs(key, B, Hq, Hkv, D, page_size, pages_per_seq, dtype,
                  num_pages=None):
    kq, kk, kv, kc = jax.random.split(key, 4)
    num_pages = num_pages or (B * pages_per_seq + 1)
    q = jax.random.normal(kq, (B, Hq, D), jnp.float32).astype(dtype)
    k_pages = jax.random.normal(
        kk, (num_pages, page_size, Hkv, D), jnp.float32).astype(dtype)
    v_pages = jax.random.normal(
        kv, (num_pages, page_size, Hkv, D), jnp.float32).astype(dtype)
    # each sequence owns a disjoint page range (as the BlockManager produces)
    tables = np.arange(B * pages_per_seq, dtype=np.int32).reshape(B, pages_per_seq)
    max_ctx = page_size * pages_per_seq
    ctx = np.asarray(jax.random.randint(kc, (B,), 1, max_ctx + 1), np.int32)
    return q, k_pages, v_pages, jnp.asarray(tables), jnp.asarray(ctx)


@pytest.mark.parametrize("B,Hq,Hkv,D,page,pps", [
    (2, 4, 4, 64, 16, 4),      # MHA
    (3, 8, 2, 64, 16, 3),      # GQA
    (1, 4, 1, 128, 32, 2),     # MQA, D=128
    (4, 2, 2, 32, 8, 5),       # small heads
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_vs_ref(B, Hq, Hkv, D, page, pps, dtype):
    q, kp, vp, bt, cl = _paged_inputs(
        jax.random.key(0), B, Hq, Hkv, D, page, pps, dtype)
    out = paged_attention(q, kp, vp, bt, cl, interpret=True)
    exp = ref.paged_attention_ref(q, kp, vp, bt, cl)
    tol = TOL if dtype == jnp.bfloat16 else dict(rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **tol)


def test_paged_scattered_tables():
    """Non-contiguous page assignment (realistic after frees/reuse)."""
    key = jax.random.key(7)
    q, kp, vp, _, _ = _paged_inputs(key, 2, 4, 2, 64, 16, 3, jnp.float32,
                                    num_pages=32)
    bt = jnp.asarray([[31, 2, 17], [9, 25, 0]], jnp.int32)
    cl = jnp.asarray([40, 33], jnp.int32)
    out = paged_attention(q, kp, vp, bt, cl, interpret=True)
    exp = ref.paged_attention_ref(q, kp, vp, bt, cl)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-4, atol=2e-4)


def test_paged_single_token_context():
    """ctx=1: softmax over one key must return exactly that value row."""
    key = jax.random.key(8)
    q, kp, vp, bt, _ = _paged_inputs(key, 1, 2, 2, 32, 8, 2, jnp.float32)
    cl = jnp.asarray([1], jnp.int32)
    out = paged_attention(q, kp, vp, bt, cl, interpret=True)
    exp = ref.paged_attention_ref(q, kp, vp, bt, cl)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(
    B=st.integers(1, 4),
    Hkv=st.sampled_from([1, 2]),
    G=st.sampled_from([1, 2, 4]),
    page=st.sampled_from([8, 16]),
    pps=st.integers(1, 5),
)
def test_paged_property_sweep(B, Hkv, G, page, pps):
    q, kp, vp, bt, cl = _paged_inputs(
        jax.random.key(3), B, Hkv * G, Hkv, 32, page, pps, jnp.float32)
    out = paged_attention(q, kp, vp, bt, cl, interpret=True)
    exp = ref.paged_attention_ref(q, kp, vp, bt, cl)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=5e-4, atol=5e-4)


# =========================================================================
# SSD scan
# =========================================================================

def _ssd_inputs(key, B, T, H, P, N, dtype=jnp.float32):
    kx, ka, kb, kc = jax.random.split(key, 4)
    xdt = jax.random.normal(kx, (B, T, H, P), jnp.float32).astype(dtype)
    # realistic decays: dA = -softplus(...) in (−∞, 0); keep moderate
    dA = -jax.nn.softplus(jax.random.normal(ka, (B, T, H), jnp.float32))
    Bm = jax.random.normal(kb, (B, T, N), jnp.float32).astype(dtype)
    Cm = jax.random.normal(kc, (B, T, N), jnp.float32).astype(dtype)
    return xdt, dA.astype(dtype), Bm, Cm


@pytest.mark.parametrize("B,T,H,P,N,chunk", [
    (1, 128, 2, 64, 32, 128),    # single chunk
    (2, 256, 2, 64, 32, 128),    # two chunks — exercises the recurrence
    (1, 512, 1, 32, 64, 128),    # four chunks
    (2, 64, 4, 16, 16, 32),      # small chunks
    (1, 96, 2, 32, 32, 32),      # T a non-power-of-two multiple of chunk
])
def test_ssd_vs_ref(B, T, H, P, N, chunk):
    xdt, dA, Bm, Cm = _ssd_inputs(jax.random.key(0), B, T, H, P, N)
    y, state = ssd_scan(xdt, dA, Bm, Cm, chunk=chunk, interpret=True)
    y_exp, state_exp = ref.ssd_scan_ref(xdt, dA, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_exp),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(state), np.asarray(state_exp),
                               rtol=2e-4, atol=2e-4)


def test_ssd_bf16_inputs():
    xdt, dA, Bm, Cm = _ssd_inputs(jax.random.key(1), 1, 128, 2, 32, 32,
                                  dtype=jnp.bfloat16)
    y, state = ssd_scan(xdt, dA, Bm, Cm, chunk=64, interpret=True)
    y_exp, state_exp = ref.ssd_scan_ref(xdt, dA, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_exp), **TOL)
    np.testing.assert_allclose(np.asarray(state), np.asarray(state_exp), **TOL)


def test_ssd_state_continuation():
    """Scanning [0:T] must equal scanning [0:T/2] then [T/2:T] with the
    carried state (the property chunked prefill of SSM archs relies on)."""
    xdt, dA, Bm, Cm = _ssd_inputs(jax.random.key(2), 1, 256, 2, 32, 32)
    y_full, s_full = ref.ssd_scan_ref(xdt, dA, Bm, Cm)
    y_a, s_a = ref.ssd_scan_ref(xdt[:, :128], dA[:, :128],
                                Bm[:, :128], Cm[:, :128])
    y_b, s_b = ref.ssd_scan_ref(xdt[:, 128:], dA[:, 128:],
                                Bm[:, 128:], Cm[:, 128:], initial_state=s_a)
    np.testing.assert_allclose(np.asarray(y_full[:, 128:]), np.asarray(y_b),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_full), np.asarray(s_b),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    T_chunks=st.integers(1, 4),
    chunk=st.sampled_from([16, 32, 64]),
    H=st.integers(1, 3),
    P=st.sampled_from([16, 32]),
    N=st.sampled_from([16, 32]),
)
def test_ssd_property_sweep(T_chunks, chunk, H, P, N):
    T = T_chunks * chunk
    xdt, dA, Bm, Cm = _ssd_inputs(jax.random.key(9), 1, T, H, P, N)
    y, state = ssd_scan(xdt, dA, Bm, Cm, chunk=chunk, interpret=True)
    y_exp, state_exp = ref.ssd_scan_ref(xdt, dA, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_exp),
                               rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(state), np.asarray(state_exp),
                               rtol=5e-4, atol=5e-4)
