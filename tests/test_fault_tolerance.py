"""Fault tolerance: checkpoint/restart of the emulated serving stack,
straggler degradation, and elastic actor membership.

The paper's §4.2.1 guarantees are "never incorrect, only slower"; these tests
extend them to full process-failure recovery: an engine snapshot taken
mid-run restores into a fresh engine and every in-flight request completes
with exactly the right number of tokens.
"""

import dataclasses
import threading
import time

import pytest

from repro.cluster import build_cluster
from repro.cluster.autoscaler import drain_victim
from repro.core.client import LocalTransport, TimeJumpClient
from repro.core.predictor import StaticPredictor
from repro.core.timekeeper import Timekeeper
from repro.serving.benchmark import BenchmarkRunner
from repro.serving.engine import LLMEngine
from repro.serving.model_runner import TimeWarpModelRunner
from repro.serving.scheduler import EngineConfig
from repro.serving.stack import build_stack
from repro.workload import WorkloadConfig, synthesize
from repro.configs import get_reduced_config


def small_cfg(**kw):
    base = dict(policy="vllm", max_num_seqs=8, max_batched_tokens=64,
                block_size=4, num_blocks=2048)
    base.update(kw)
    return EngineConfig(**base)


def small_workload(n=20, qps=50.0, seed=0):
    return synthesize(WorkloadConfig(
        num_requests=n, qps=qps, prompt_len_mean=24, output_len_mean=8,
        max_prompt_len=64, max_output_len=16, seed=seed))


MODEL = get_reduced_config("qwen2_5_3b")


# =========================================================================
# checkpoint / restart
# =========================================================================

def test_snapshot_restore_mid_run():
    """Kill the engine halfway; restore from snapshot; everything finishes."""
    reqs = small_workload(n=16)
    stack = build_stack(MODEL, small_cfg(), "emulate",
                        predictor=StaticPredictor(5e-3),
                        use_worker_group=False)
    eng = stack.engine.start()
    for r in reqs[:10]:
        eng.submit(r)
    # let roughly half the work land
    eng.wait_until_complete(4, timeout=30)
    blob = eng.snapshot()
    n_done_at_snap = len(eng.finished)
    stack.shutdown()                       # "node failure"

    # restore into a brand-new stack (fresh Timekeeper + runner)
    stack2 = build_stack(MODEL, small_cfg(), "emulate",
                         predictor=StaticPredictor(5e-3),
                         use_worker_group=False)
    stack2.timekeeper.close()              # replace engine wholesale
    tk = Timekeeper(jitter_cooldown=0.0)
    tr = LocalTransport(tk)
    client = TimeJumpClient(tr, "restored-worker")
    runner = TimeWarpModelRunner(StaticPredictor(5e-3), client)
    eng2 = LLMEngine.restore(blob, runner, tk.clock, name="restored")
    eng2.start()
    for r in reqs[10:]:                    # traffic keeps arriving
        eng2.submit(r)
    ok = eng2.wait_until_complete(16 - n_done_at_snap, timeout=60)
    assert ok, "restored engine must drain all in-flight + new requests"
    eng2.stop()
    tk.close()

    all_done = {r.request_id for r in eng2.finished} | {
        r.request_id for r in reqs[:10] if r.request_id in
        {x.request_id for x in eng2.finished}}
    for r in reqs:
        pass
    # every request finished exactly once with the right token count
    finished_ids = [r.request_id for r in eng2.finished]
    assert len(finished_ids) == len(set(finished_ids)), "duplicate completion"
    for r in eng2.finished:
        assert r.num_generated == r.max_new_tokens


def test_snapshot_preserves_virtual_clock():
    stack = build_stack(MODEL, small_cfg(), "emulate",
                        predictor=StaticPredictor(10e-3),
                        use_worker_group=False)
    eng = stack.engine.start()
    for r in small_workload(n=6, qps=100.0):
        eng.submit(r)
    eng.wait_until_complete(6, timeout=30)
    t_before = eng.clock.now()
    offset_before = eng.clock.offset
    blob = eng.snapshot()
    stack.shutdown()

    tk = Timekeeper(jitter_cooldown=0.0)
    runner = TimeWarpModelRunner(
        StaticPredictor(10e-3), TimeJumpClient(LocalTransport(tk), "w"))
    eng2 = LLMEngine.restore(blob, runner, tk.clock)
    # restored virtual clock resumes at (or after) the snapshot time: history
    # is never re-lived, so latency measurements stay consistent
    assert eng2.clock.now() >= t_before - 1e-3
    assert tk.clock.offset >= offset_before - 1e-3
    tk.close()


def test_restored_requests_recompute_from_scratch():
    """Running requests lose device KV on failure; they must re-queue as
    WAITING with zeroed progress (idempotent replay)."""
    from repro.serving.request import Request, RequestState
    stack = build_stack(MODEL, small_cfg(max_batched_tokens=8), "emulate",
                        predictor=StaticPredictor(50e-3),
                        use_worker_group=False)
    eng = stack.engine               # not started: step manually for determinism
    big = Request(prompt_tokens=list(range(1, 65)), max_new_tokens=4)
    eng.scheduler.add_request(big)
    eng.step(); eng.step()           # two 8-token chunks of the 64-token prompt
    assert 0 < big.num_prefilled < big.prompt_len
    blob = eng.snapshot()
    stack.shutdown()

    tk = Timekeeper(jitter_cooldown=0.0)
    runner = TimeWarpModelRunner(
        StaticPredictor(1e-3), TimeJumpClient(LocalTransport(tk), "w"))
    eng2 = LLMEngine.restore(blob, runner, tk.clock)
    restored = list(eng2.scheduler.waiting)
    assert any(r.request_id == big.request_id for r in restored)
    rr = next(r for r in restored if r.request_id == big.request_id)
    assert rr.num_prefilled == 0 and rr.state == RequestState.WAITING
    eng2.start()
    assert eng2.wait_until_complete(1, timeout=30)
    assert eng2.finished[0].num_generated == 4
    eng2.stop()
    tk.close()


def test_snapshot_round_trips_preemption_state_deterministically():
    """Regression (non-blocking submit path): a snapshot must capture the
    scheduler's preemption/waiting state between steps — never a torn
    mid-step state — and restore it verbatim.  Drive an engine into
    preemption manually, snapshot, and check the restored scheduler queues
    are byte-equivalent across repeated restores."""
    from repro.serving.request import Request, RequestState

    # tiny KV pool so two long decodes collide -> preemption-by-recompute
    stack = build_stack(MODEL, small_cfg(num_blocks=8, max_batched_tokens=64,
                                         enable_prefix_caching=False),
                        "emulate", predictor=StaticPredictor(1e-3),
                        use_worker_group=False)
    eng = stack.engine                 # not started: step manually
    ra = Request(prompt_tokens=list(range(1, 13)), max_new_tokens=20)
    rb = Request(prompt_tokens=list(range(101, 113)), max_new_tokens=20)
    eng.scheduler.add_request(ra)
    eng.scheduler.add_request(rb)
    for _ in range(40):
        eng.step()
        if eng.scheduler.num_preemptions >= 1:
            break
    assert eng.scheduler.num_preemptions >= 1, "setup must trigger preemption"
    assert eng.scheduler.waiting, "preempted request must sit in waiting"
    blob = eng.snapshot()
    stack.shutdown()

    def restored_state():
        tk = Timekeeper(jitter_cooldown=0.0)
        runner = TimeWarpModelRunner(
            StaticPredictor(1e-3), TimeJumpClient(LocalTransport(tk), "w"))
        eng2 = LLMEngine.restore(blob, runner, tk.clock)
        state = [(r.request_id, r.state, r.num_prefilled, r.num_preemptions)
                 for r in eng2.scheduler.waiting]
        return eng2, tk, state

    eng_a, tk_a, state_a = restored_state()
    eng_b, tk_b, state_b = restored_state()
    assert state_a == state_b, "restore must be deterministic"
    # scheduler counters round-trip (not reset to zero)
    assert eng_a.scheduler.num_preemptions >= 1
    # preempted requests re-enter with zeroed progress, ready for recompute
    for rid, state, prefilled, nprempt in state_a:
        assert state in (RequestState.WAITING, RequestState.PREEMPTED)
        assert prefilled == 0
    # and the restored engine still drains everything exactly
    eng_a.start()
    assert eng_a.wait_until_complete(2, timeout=60)
    for r in eng_a.finished:
        assert r.num_generated == r.max_new_tokens
    eng_a.stop()
    tk_a.close()
    tk_b.close()


def test_snapshot_never_tears_a_running_step():
    """Concurrent snapshots while the engine thread is stepping and the
    dispatcher keeps submitting must always observe a consistent
    between-steps state: every request is in exactly one queue and token
    counts are internally coherent."""
    import pickle as _pickle

    reqs = small_workload(n=24, qps=500.0)
    stack = build_stack(MODEL, small_cfg(), "emulate",
                        predictor=StaticPredictor(2e-3),
                        use_worker_group=False)
    eng = stack.engine.start()
    blobs = []
    for i, r in enumerate(reqs):
        eng.submit(r)
        if i % 4 == 0:
            blobs.append(eng.snapshot())     # racing the step loop
    eng.wait_until_complete(len(reqs), timeout=60)
    blobs.append(eng.snapshot())
    stack.shutdown()

    all_ids = {r.request_id for r in reqs}
    for blob in blobs:
        state = _pickle.loads(blob)
        seen = [r.request_id for pool in ("waiting", "running", "inbox",
                                          "finished")
                for r in state[pool]]
        assert len(seen) == len(set(seen)), "request in two queues at once"
        assert set(seen) <= all_ids
        for r in state["running"]:
            # a torn snapshot would capture prefill progress beyond the
            # prompt without the decode transition having been applied
            assert r.num_prefilled <= r.prompt_len
            assert r.num_generated <= r.max_new_tokens
        for r in state["finished"]:
            assert r.num_generated == r.max_new_tokens


# =========================================================================
# straggler mitigation / graceful degradation
# =========================================================================

def test_straggler_degrades_to_wall_clock_never_wrong():
    """An actor that stops responding mid-barrier costs wall time but the
    other actor's TIMEJUMP still returns with the correct virtual target.
    Wall time is a ManualWallSource: the degradation *accounting* (virtual
    progress is paid for in wall seconds) is asserted exactly, without the
    test itself sleeping on the real clock."""
    from repro.core.clock import ManualWallSource, VirtualClock
    wall = ManualWallSource()
    tk = Timekeeper(VirtualClock(wall), jitter_cooldown=0.0)
    tr = LocalTransport(tk)
    fast = TimeJumpClient(tr, "fast")
    straggler = TimeJumpClient(tr, "straggler")   # registers, never jumps

    t0 = fast.now()
    wall0 = wall.time()
    done = threading.Event()
    result = {}

    def jump():
        result["t1"] = fast.time_jump(0.15)   # timeout path: rides wall
        done.set()

    th = threading.Thread(target=jump)
    th.start()
    # drive the manual wall forward until the degraded jump completes; the
    # barrier never resolves (the straggler never jumps), so the only way
    # the jump can return is by paying these wall seconds
    for _ in range(10_000):
        if done.wait(0.0005):
            break
        wall.advance(0.01)
    th.join(10)
    assert done.is_set(), "degraded jump must complete once wall flows"
    spent = wall.time() - wall0
    assert result["t1"] >= t0 + 0.15 - 1e-6, \
        "virtual target must still be reached"
    assert spent >= 0.15 - 1e-6, "degradation means paying wall clock"
    fast.deregister()
    straggler.deregister()
    tk.close()


def test_straggler_recovers_acceleration():
    """After the straggler departs (elastic deregistration), the remaining
    actor's jumps resolve instantly again."""
    tk = Timekeeper(jitter_cooldown=0.0)
    tr = LocalTransport(tk)
    fast = TimeJumpClient(tr, "fast")
    straggler = TimeJumpClient(tr, "straggler")
    straggler.deregister()        # elastic scale-down re-evaluates barrier

    wall0 = time.monotonic()
    fast.time_jump(5.0)           # would take 5 s wall if degraded
    wall = time.monotonic() - wall0
    assert wall < 1.0, "sole remaining actor must jump at full speed"
    fast.deregister()
    tk.close()


def test_engine_park_prevents_barrier_wedge():
    """An idle engine must not stall the dispatcher's time jumps: parking
    deregisters its actors (regression test for the idle-wedge)."""
    stack = build_stack(MODEL, small_cfg(), "emulate",
                        predictor=StaticPredictor(1e-3),
                        use_worker_group=False)
    eng = stack.engine.start()
    assert eng._idle.wait(10.0), "engine must park (no work)"
    client = TimeJumpClient(stack.transport, "probe")
    wall0 = time.monotonic()
    client.time_jump(10.0)        # must resolve without the engine
    assert time.monotonic() - wall0 < 2.0
    client.deregister()
    stack.shutdown()


# =========================================================================
# chaos fault matrix: {crash, straggler, spot_reclaim} × backend × policy
# =========================================================================

def _chaos_cell(kind, on_crash):
    """One matrix cell as a Scenario: the chaos presets re-pointed at one
    fault kind with the requested on-crash policy.  Fault times are the
    presets' verified mid-decode instants, so the fault always has victims
    (requeue/fail counts are deterministic, not racy)."""
    from repro.scenario import get_preset
    if kind == "crash":
        base = get_preset("crash_recovery")
        faults = tuple(dataclasses.replace(f, on_crash=on_crash)
                       for f in base.faults)
    elif kind == "straggler":
        base = get_preset("chaos_spot")
        faults = tuple(f for f in base.faults if f.kind == "straggler")
    else:
        base = get_preset("chaos_spot")
        faults = tuple(dataclasses.replace(f, on_crash=on_crash)
                       if f.kind == "spot_reclaim" else f
                       for f in base.faults)
    return dataclasses.replace(base, name=f"{kind}_{on_crash}",
                               faults=faults)


FAULT_MATRIX = [("crash", "requeue"), ("crash", "fail"),
                ("straggler", "requeue"),
                ("spot_reclaim", "requeue"), ("spot_reclaim", "fail")]


@pytest.mark.timeout(180)
@pytest.mark.parametrize("backend", ["thread", "process", "des"])
@pytest.mark.parametrize("kind,on_crash", FAULT_MATRIX)
def test_fault_matrix_conservation(kind, on_crash, backend):
    """Every fault kind on every backend under both crash policies:
    completed + failed == submitted (nothing lost, nothing duplicated),
    the fault is actually applied, and fail-policy runs never requeue."""
    from repro.scenario import run
    scenario = _chaos_cell(kind, on_crash)
    res = run(scenario, backend=backend, timeout=120)
    n = scenario.workload.num_requests
    assert res.num_requests + res.requests_failed == n, (
        f"conservation violated: {res.num_requests} completed + "
        f"{res.requests_failed} failed != {n} submitted")
    kinds = {e[0] for e in res.faults_injected}
    if kind == "crash":
        assert {"crash", "respawn"} <= kinds
        hit = (res.requests_requeued if on_crash == "requeue"
               else res.requests_failed)
        assert hit == 1, "the preset crash instant is mid-decode"
    elif kind == "straggler":
        assert {"straggle", "straggle_end"} <= kinds
        assert res.num_requests == n
    else:
        assert {"reclaim", "reclaim_kill", "respawn"} <= kinds
        hit = (res.requests_requeued if on_crash == "requeue"
               else res.requests_failed)
        assert hit == 1, "the notice window is too short to drain"
    if on_crash == "fail":
        assert res.requests_requeued == 0
    # each completion measured exactly once in the audit trail
    assert len(res.latencies) == res.num_requests


@pytest.mark.timeout(300)
@pytest.mark.parametrize("preset", ["crash_recovery", "chaos_spot"])
def test_chaos_preset_three_way_parity(preset):
    """The acceptance bar: both chaos presets through thread / process /
    DES must produce the identical fault log (same faults at the same
    virtual instants, same requeue/fail outcomes), identical routing
    decisions, and latencies within one slow-step.  ``compare`` raises
    ParityError on any divergence."""
    from repro.scenario import compare, get_preset
    cres = compare(get_preset(preset),
                   backends=("thread", "process", "des"), timeout=240)
    assert cres.faults_equal and cres.decisions_equal
    assert cres.max_err_steps <= 1.0
    logs = [tuple(r.faults_injected) for r in cres.results.values()]
    assert len(set(logs)) == 1 and logs[0], "fault logs must match exactly"


@pytest.mark.timeout(180)
@pytest.mark.parametrize("on_crash", ["requeue", "fail"])
def test_process_backend_sigkill_exact_tokens(on_crash):
    """Crash on the process backend is a real SIGKILL of the replica child;
    the parent recovers in-flight requests from its submission ledger and
    the run still completes with exact token counts — no lost and no
    duplicated completions."""
    cluster = build_cluster(MODEL, small_cfg(), 2,
                            predictor=StaticPredictor(5e-3),
                            backend="process")
    try:
        cluster.start()
        reqs = small_workload(n=12, qps=500.0, seed=7)
        ids = {r.request_id for r in reqs}
        for r in reqs:
            cluster.submit(r)
        out = cluster.crash_replica(1, on_crash=on_crash)
        assert out["crashed"], "child must be killable mid-run"
        # the child OS process is really gone (SIGKILL, not a drain)
        assert not cluster.replicas[1].proc.is_alive()
        assert cluster.wait_until_complete(len(reqs), timeout=120)
        finished = list(cluster.finished)
        failed = list(cluster.failed)
        fids = [r.request_id for r in finished]
        assert len(fids) == len(set(fids)), "duplicate completion"
        assert len(finished) + len(failed) == len(reqs)
        assert set(fids) | {r.request_id for r in failed} == ids
        assert not (set(fids) & {r.request_id for r in failed})
        for r in finished:
            assert r.num_generated == r.max_new_tokens
        if on_crash == "requeue":
            assert not failed and out["requeued"] > 0
        else:
            assert len(failed) == out["failed"] > 0
    finally:
        cluster.shutdown()


def test_crash_while_draining_not_refinalized_or_double_billed():
    """Regression: a replica that crashes *while draining* must (a) leave
    the drain ledger so later completions never re-finalize it, (b) never
    be a future drain victim, and (c) close its billing window exactly once
    at the crash instant.  Engines are deliberately not started, so the
    in-flight set at drain time is deterministic."""
    cluster = build_cluster(MODEL, small_cfg(), 3,
                            predictor=StaticPredictor(5e-3))
    try:
        reqs = small_workload(n=6, qps=1000.0, seed=5)
        for r in reqs:
            cluster.submit(r)               # round robin: 2 per replica
        cluster.drain_replica(1)
        assert 1 in cluster._draining, "drain must be pending (in-flight)"
        out = cluster.crash_replica(1, on_crash="requeue")
        assert out["crashed"] and out["requeued"] == 2
        assert 1 not in cluster._draining
        m_crash = cluster.membership_events()[1]
        assert m_crash["drained"] is not None
        # (a) delivering the requeued work's completions later must not
        # re-finalize the membership record
        cluster._drain_progress(reqs)
        assert cluster.membership_events()[1] == m_crash
        # (b) gone from the routing set -> drain_victim can't pick it
        assert 1 not in cluster.active
        victim = drain_victim(cluster.active, idle_of=lambda i: True,
                              cost_of=lambda i: 1.0)
        assert victim != 1
        # (c) billed exactly once: replica 1's window closes at the crash
        # stamp, so a window starting there bills only the two survivors —
        # a leaked drain ledger entry would bill it through the window end
        t_crash = m_crash["drained"]
        assert cluster.replica_seconds(t_crash, t_crash + 10.0) == \
            pytest.approx(20.0)
    finally:
        cluster.shutdown()


@pytest.mark.timeout(180)
def test_crash_while_draining_parity_and_single_billing():
    """The chaos_spot reclaim IS a crash-while-draining (drain notice too
    short, kill lands mid-decode): thread and DES must agree on the drain
    record (victim drained exactly once) and bill the same replica-seconds
    and dollars — double-counting a crashed-while-draining replica would
    show up as a cost divergence."""
    from repro.scenario import compare, get_preset
    cres = compare(get_preset("chaos_spot"), backends=("thread", "des"),
                   timeout=120)
    thread, des = cres.results["thread"], cres.results["des"]
    kill = next(e for e in thread.faults_injected
                if e[0] == "reclaim_kill")
    assert kill[5], "the reclaim kill must land mid-drain (crashed=True)"
    assert thread.drained == des.drained
    assert thread.drained.count(2) == 1, "victim finalized exactly once"
    assert thread.replica_seconds == pytest.approx(des.replica_seconds,
                                                   rel=1e-9)
    assert thread.cost_dollars == pytest.approx(des.cost_dollars, rel=1e-9)


# =========================================================================
# elastic scaling
# =========================================================================

def test_actors_join_and_leave_between_rounds():
    tk = Timekeeper(jitter_cooldown=0.0)
    tr = LocalTransport(tk)
    a = TimeJumpClient(tr, "a")
    b = TimeJumpClient(tr, "b")

    done = []

    def jump(client, dt):
        client.time_jump(dt)
        done.append(client.actor_id)

    th_a = threading.Thread(target=jump, args=(a, 0.05))
    th_b = threading.Thread(target=jump, args=(b, 0.05))
    th_a.start(); th_b.start()
    th_a.join(5); th_b.join(5)
    assert sorted(done) == ["a", "b"]

    # scale up: a third actor joins and participates
    c = TimeJumpClient(tr, "c")
    done.clear()
    ths = [threading.Thread(target=jump, args=(cl, 0.02)) for cl in (a, b, c)]
    for t in ths: t.start()
    for t in ths: t.join(5)
    assert sorted(done) == ["a", "b", "c"]
    assert tk.stats.registered_peak == 3
    for cl in (a, b, c):
        cl.deregister()
    tk.close()


def test_elastic_worker_group_resize():
    """TP worker-group grows/shrinks between steps without wedging."""
    from repro.serving.workers import WorkerGroup
    tk = Timekeeper(jitter_cooldown=0.0)
    tr = LocalTransport(tk)
    wg = WorkerGroup(tr, 2, name="g")
    t0 = tk.clock.now()
    wg.execute_step(0.05)
    assert tk.clock.now() >= t0 + 0.05 - 1e-6
    wg.resize(4)
    wg.execute_step(0.05)
    assert tk.clock.now() >= t0 + 0.10 - 1e-6
    wg.resize(1)
    wg.execute_step(0.05)
    assert tk.clock.now() >= t0 + 0.15 - 1e-6
    wg.shutdown()
    tk.close()
