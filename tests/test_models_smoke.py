"""Per-architecture smoke tests (assignment requirement).

For each of the 10 assigned architectures (+ the paper's 3 eval models):
instantiate the REDUCED same-family config, run one forward/train step and a
prefill→decode round-trip on CPU, and assert output shapes + finiteness.
The FULL configs are exercised only via the dry-run (no allocation here).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, PAPER_ARCH_IDS, get_reduced_config
from repro.models.optim import OptimizerConfig, init_adamw, make_train_step
from repro.models.transformer import build_model

ALL_IDS = ARCH_IDS + PAPER_ARCH_IDS


def _train_batch(cfg, key, B=2, S=32):
    tb = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
    }
    if cfg.frontend is not None:
        tb["frontend_embeds"] = 0.02 * jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.d_model), jnp.float32)
    return tb


@pytest.mark.parametrize("arch", ALL_IDS)
def test_forward_and_loss(arch):
    cfg = get_reduced_config(arch)
    model = build_model(cfg)
    key = jax.random.key(0)
    params = model.init(key, jnp.float32)
    batch = _train_batch(cfg, key)
    loss, metrics = jax.jit(
        lambda p, b: model.train_loss(p, b, remat=False))(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss is not finite"
    assert float(loss) > 0.0
    # CE of a random init should be near ln(vocab)
    assert float(loss) < 2.0 * np.log(cfg.vocab_size) + 5.0


@pytest.mark.parametrize("arch", ALL_IDS)
def test_train_step(arch):
    cfg = get_reduced_config(arch)
    model = build_model(cfg)
    key = jax.random.key(1)
    params = model.init(key, jnp.float32)
    opt = init_adamw(params)
    step = make_train_step(model, OptimizerConfig(warmup_steps=1),
                           microbatches=1, remat=False)
    batch = _train_batch(cfg, key)
    new_params, new_opt, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(new_opt.step) == 1
    # parameters actually moved
    moved = jax.tree.reduce(
        lambda acc, x: acc + float(jnp.sum(jnp.abs(x))),
        jax.tree.map(lambda a, b: (a - b).astype(jnp.float32),
                     new_params, params), 0.0)
    assert moved > 0.0, f"{arch}: train step was a no-op"


@pytest.mark.parametrize("arch", ALL_IDS)
def test_microbatched_train_step_matches(arch):
    """Gradient accumulation must be equivalent to the monolithic step."""
    cfg = get_reduced_config(arch)
    model = build_model(cfg)
    key = jax.random.key(2)
    params = model.init(key, jnp.float32)
    batch = _train_batch(cfg, key, B=4, S=16)

    def loss_of(mb):
        step = make_train_step(model, OptimizerConfig(), microbatches=mb,
                               remat=False)
        _, _, metrics = jax.jit(step)(params, init_adamw(params), batch)
        return float(metrics["loss"])

    # MoE: the load-balance aux loss is quadratic in per-batch routing
    # fractions, so mean-of-microbatch aux != full-batch aux (~0.3%); the
    # CE term itself is split-invariant.
    rel = 1e-2 if cfg.moe is not None else 1e-4
    assert loss_of(1) == pytest.approx(loss_of(2), rel=rel)


@pytest.mark.parametrize("arch", ALL_IDS)
def test_prefill_decode_consistency(arch):
    """prefill(T tokens) then decode must agree with prefill(T+1 tokens)."""
    cfg = get_reduced_config(arch)
    model = build_model(cfg)
    key = jax.random.key(3)
    params = model.init(key, jnp.float32)
    B, T = 2, 12
    toks = np.asarray(jax.random.randint(key, (B, T + 1), 0, cfg.vocab_size))

    kw = {}
    inputs_full = {"tokens": jnp.asarray(toks)}
    inputs_pre = {"tokens": jnp.asarray(toks[:, :T])}
    if cfg.frontend is not None:
        fe = 0.02 * np.asarray(jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.d_model), jnp.float32))
        inputs_full["frontend_embeds"] = jnp.asarray(fe)
        inputs_pre["frontend_embeds"] = jnp.asarray(fe)

    cache_a = model.init_cache(B, 64, jnp.float32)
    logits_full, _ = model.prefill(params, inputs_full, cache_a)

    cache_b = model.init_cache(B, 64, jnp.float32)
    _, cache_b = model.prefill(params, inputs_pre, cache_b)
    logits_step, _ = model.decode_step(
        params, cache_b, jnp.asarray(toks[:, T:]))

    np.testing.assert_allclose(
        np.asarray(logits_full), np.asarray(logits_step),
        rtol=2e-3, atol=2e-3,
        err_msg=f"{arch}: incremental decode diverges from full prefill")


@pytest.mark.parametrize("arch", ALL_IDS)
def test_chunked_prefill_consistency(arch):
    """Two prefill chunks must equal one monolithic prefill (the property
    chunked-prefill serving relies on)."""
    cfg = get_reduced_config(arch)
    if cfg.frontend is not None:
        pytest.skip("frontend embeds arrive with the first chunk only")
    model = build_model(cfg)
    key = jax.random.key(4)
    params = model.init(key, jnp.float32)
    B, T = 1, 16
    toks = np.asarray(jax.random.randint(key, (B, T), 0, cfg.vocab_size))

    cache_a = model.init_cache(B, 64, jnp.float32)
    logits_full, _ = model.prefill(
        params, {"tokens": jnp.asarray(toks)}, cache_a)

    cache_b = model.init_cache(B, 64, jnp.float32)
    _, cache_b = model.prefill(
        params, {"tokens": jnp.asarray(toks[:, :T // 2])}, cache_b)
    logits_chunk, _ = model.prefill(
        params, {"tokens": jnp.asarray(toks[:, T // 2:])}, cache_b)

    np.testing.assert_allclose(
        np.asarray(logits_full), np.asarray(logits_chunk),
        rtol=2e-3, atol=2e-3,
        err_msg=f"{arch}: chunked prefill diverges from monolithic prefill")


@pytest.mark.parametrize("arch", ALL_IDS)
def test_param_count_accounting(arch):
    """config.param_count() must match the real parameter tree exactly —
    the analytical predictor and the roofline both trust it."""
    cfg = get_reduced_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0), jnp.float32)
    actual = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    expected = cfg.param_count()
    assert actual == expected, (
        f"{arch}: param tree has {actual}, config accounts {expected} "
        f"(Δ={actual - expected})")


def test_sliding_window_bounds_cache():
    """SWA archs must allocate window-sized KV, not context-sized."""
    cfg = get_reduced_config("mixtral_8x7b").replace(sliding_window=8)
    model = build_model(cfg)
    cache = model.init_cache(1, 4096, jnp.float32)
    k = jax.tree.leaves(cache["layers"])[0]
    assert cache["layers"]["k"].shape[2] == 8  # (L, B, S=window, H, D)


def test_long_context_flags():
    from repro.configs import get_config
    assert get_config("mamba2_370m").supports_long_context()
    assert get_config("recurrentgemma_2b").supports_long_context()
    assert get_config("mixtral_8x7b").supports_long_context()
    assert not get_config("qwen2_5_3b").supports_long_context()
    assert not get_config("whisper_base").supports_long_context()
    assert not get_config("dbrx_132b").supports_long_context()
