"""Serving control-plane tests: block manager, radix prefix cache, scheduler
policies — unit coverage plus hypothesis property tests on the invariants the
engine relies on (refcount conservation, no phantom blocks, policy split).
"""

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                              # optional dev dependency
    from _hypothesis_compat import given, settings, st

from repro.serving.kv_cache import BlockManager, OutOfBlocksError
from repro.serving.prefix_cache import RadixPrefixCache
from repro.serving.request import Request, RequestState
from repro.serving.scheduler import EngineConfig, Scheduler


def make_req(prompt_len=32, out=8, rid=None, arrival=0.0, vocab=1000, seed=0):
    import numpy as np
    rng = np.random.default_rng(seed if rid is None else rid)
    kw = {"request_id": rid} if rid is not None else {}
    return Request(
        prompt_tokens=rng.integers(1, vocab, size=prompt_len).tolist(),
        max_new_tokens=out, arrival_time=arrival, **kw)


def make_sched(policy="vllm", num_blocks=64, block_size=4, budget=16,
               max_seqs=8, prefix=True, host_blocks=0,
               host_policy="write_through"):
    cfg = EngineConfig(policy=policy, max_num_seqs=max_seqs,
                       max_batched_tokens=budget, block_size=block_size,
                       num_blocks=num_blocks, enable_prefix_caching=prefix,
                       host_tier_blocks=host_blocks,
                       host_write_policy=host_policy)
    bm = BlockManager(num_blocks, block_size)
    pc = RadixPrefixCache(bm, enable=prefix, host_tier_blocks=host_blocks,
                          host_write_policy=host_policy)
    return cfg, bm, pc, Scheduler(cfg, bm, pc)


def drive(sched, now=0.0, steps=1):
    """Run scheduler steps, feeding back dummy tokens."""
    outs = []
    for i in range(steps):
        out = sched.schedule(now + i)
        toks = {s.request.request_id: 1 for s in out.batch}
        sched.on_step_complete(out, toks, now + i + 0.5)
        outs.append(out)
    return outs


# =========================================================================
# block manager
# =========================================================================

def test_block_allocation_and_free():
    bm = BlockManager(16, 4)
    r = make_req(prompt_len=10)
    bm.allocate_request(r)
    assert len(bm.block_tables[r.request_id]) == 3       # ceil(10/4)
    assert bm.num_free == 13
    released = bm.free_request(r)
    assert bm.num_free == 16 and len(released) == 3


def test_append_slot_grows_table():
    bm = BlockManager(16, 4)
    r = make_req(prompt_len=4)
    bm.allocate_request(r)
    r.num_prefilled = 4
    assert len(bm.block_tables[r.request_id]) == 1
    bm.append_slot(r)     # token 5 -> needs block 2
    assert len(bm.block_tables[r.request_id]) == 2


def test_out_of_blocks_raises():
    bm = BlockManager(2, 4)
    r = make_req(prompt_len=12)
    with pytest.raises(OutOfBlocksError):
        bm.allocate_request(r)


def test_shared_prefix_refcounting():
    bm = BlockManager(16, 4)
    r1 = make_req(prompt_len=8, rid=1001)
    bm.allocate_request(r1)
    shared = list(bm.block_tables[1001])
    r2 = make_req(prompt_len=8, rid=1002)
    bm.allocate_request(r2, cached_blocks=shared)
    assert bm.block_tables[1002] == shared               # fully shared
    bm.free_request(r1)
    assert bm.num_free == 14                             # still referenced
    bm.free_request(r2)
    assert bm.num_free == 16


@settings(max_examples=60, deadline=None)
@given(st.lists(
    st.tuples(st.sampled_from(["alloc", "free", "append"]),
              st.integers(0, 7), st.integers(1, 40)),
    min_size=1, max_size=60))
def test_block_manager_conservation(ops):
    """Property: blocks are conserved — free + sum(refcounted uniques) is
    constant, refcounts never negative, and tables never contain freed
    blocks."""
    bm = BlockManager(32, 4)
    live = {}
    for kind, slot, plen in ops:
        rid = 5000 + slot
        if kind == "alloc" and rid not in live:
            r = make_req(prompt_len=plen, rid=rid)
            try:
                bm.allocate_request(r)
                live[rid] = r
            except OutOfBlocksError:
                pass
        elif kind == "free" and rid in live:
            bm.free_request(live.pop(rid))
        elif kind == "append" and rid in live:
            r = live[rid]
            r.num_prefilled = r.prompt_len
            r.output_tokens.append(1)
            try:
                bm.append_slot(r)
            except OutOfBlocksError:
                pass
        # invariants
        used = set()
        for t in bm.block_tables.values():
            used.update(t)
        assert used.isdisjoint(bm._free), "freed block still referenced"
        for b in bm._blocks:
            assert b.ref_count >= 0
        held = sum(1 for b in bm._blocks if b.ref_count > 0)
        assert held + bm.num_free == 32


# =========================================================================
# radix prefix cache
# =========================================================================

def test_prefix_match_after_insert():
    _, bm, pc, _ = make_sched()
    r = make_req(prompt_len=16, rid=2001)
    bm.allocate_request(r)
    table = bm.block_tables[2001]
    pc.insert(r.prompt_tokens, table, now=1.0)
    blocks, n_dev, n_host = pc.match(r.prompt_tokens, now=2.0)
    assert n_dev == 16 and blocks == table
    # a diverging suffix matches only the shared prefix
    blocks2, n2, _ = pc.match(list(r.prompt_tokens[:8]) + [9999] * 8, now=3.0)
    assert n2 == 8 and blocks2 == table[:2]


def test_prefix_cache_keeps_blocks_alive():
    _, bm, pc, _ = make_sched(num_blocks=8)
    r = make_req(prompt_len=16, rid=2002)
    bm.allocate_request(r)
    pc.insert(r.prompt_tokens, bm.block_tables[2002], now=1.0)
    bm.free_request(r)
    assert bm.num_free == 4          # 4 blocks pinned by the cache
    assert pc.evict(99, now=2.0) == 4
    assert bm.num_free == 8


def test_eviction_is_lru():
    _, bm, pc, _ = make_sched(num_blocks=16)
    ra = make_req(prompt_len=4, rid=2003, seed=1)
    rb = make_req(prompt_len=4, rid=2004, seed=2)
    for r, t in ((ra, 1.0), (rb, 2.0)):
        bm.allocate_request(r)
        pc.insert(r.prompt_tokens, bm.block_tables[r.request_id], now=t)
        bm.free_request(r)
    pc.match(ra.prompt_tokens, now=3.0)     # touch A -> B becomes LRU
    assert pc.evict(1, now=4.0) == 1
    blocks, n_dev, _ = pc.match(rb.prompt_tokens, now=5.0)
    assert n_dev == 0, "LRU (B) should have been evicted"
    _, n_dev_a, _ = pc.match(ra.prompt_tokens, now=6.0)
    assert n_dev_a == 4


def test_host_tier_write_policies():
    """vLLM writes through on insert; SGLang promotes on first hit."""
    _, bm_wt, pc_wt, _ = make_sched(host_blocks=64)
    _, bm_sel, pc_sel, _ = make_sched(host_blocks=64,
                                      host_policy="write_through_selective")
    r = make_req(prompt_len=16, rid=2005)
    for bm, pc in ((bm_wt, pc_wt), (bm_sel, pc_sel)):
        rr = make_req(prompt_len=16, rid=2005 + id(pc) % 7)
        rr.prompt_tokens = r.prompt_tokens
        bm.allocate_request(rr)
        pc.insert(rr.prompt_tokens, bm.block_tables[rr.request_id], now=1.0)
    assert len(pc_wt._host) == 4          # write-through: immediate
    assert len(pc_sel._host) == 0         # selective: not yet
    pc_sel.match(r.prompt_tokens, now=2.0)
    assert len(pc_sel._host) == 4         # promoted on first hit


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(0, 3), min_size=2, max_size=24),
       st.integers(1, 5))
def test_radix_property_match_is_prefix_consistent(prompt_pool, n_evict):
    """Property: after arbitrary insert/evict, any match result is (a) block-
    aligned, (b) a true prefix of the query, (c) never exceeds what was
    inserted."""
    _, bm, pc, _ = make_sched(num_blocks=128, block_size=2)
    inserted = []
    for i in range(4):
        toks = [(p + i) % 7 for p in prompt_pool] * 2
        toks = toks[: max(2, (len(toks) // 2) * 2)]
        r = make_req(prompt_len=len(toks), rid=3000 + i)
        r.prompt_tokens = toks
        bm.allocate_request(r)
        pc.insert(toks, bm.block_tables[r.request_id], now=float(i))
        inserted.append(toks)
        bm.free_request(r)
    pc.evict(n_evict, now=10.0)
    for toks in inserted:
        blocks, n_dev, _ = pc.match(toks, now=20.0)
        assert n_dev % 2 == 0
        assert n_dev <= len(toks)
        assert len(blocks) == n_dev // 2
        # every matched block's recorded tokens equal the query prefix chunk
        for j, bid in enumerate(blocks):
            assert tuple(toks[j * 2:(j + 1) * 2]) == bm._blocks[bid].token_ids


# =========================================================================
# scheduler policies
# =========================================================================

def test_vllm_policy_mixes_prefill_and_decode():
    cfg, bm, pc, sched = make_sched(policy="vllm", budget=8)
    ra = make_req(prompt_len=6, out=4, rid=4001)
    sched.add_request(ra)
    drive(sched, now=0.0)                      # ra prefills fully
    rb = make_req(prompt_len=20, out=4, rid=4002)
    sched.add_request(rb)
    out = sched.schedule(1.0)
    kinds = {(s.request.request_id, s.is_prefill) for s in out.batch}
    assert (4001, False) in kinds, "running decode must stay in the batch"
    assert (4002, True) in kinds, "prefill chunk must be co-scheduled"
    # budget respected: decode(1) + chunk(<=7)
    assert sum(s.num_new_tokens for s in out.batch) <= 8


def test_sglang_policy_never_mixes():
    cfg, bm, pc, sched = make_sched(policy="sglang", budget=8)
    ra = make_req(prompt_len=6, out=4, rid=4003)
    sched.add_request(ra)
    drive(sched, now=0.0)
    rb = make_req(prompt_len=20, out=4, rid=4004)
    sched.add_request(rb)
    seen_mixed = False
    for _ in range(8):
        out = sched.schedule(1.0)
        if out.is_empty:
            break
        has_p = any(s.is_prefill for s in out.batch)
        has_d = any(not s.is_prefill for s in out.batch)
        seen_mixed |= (has_p and has_d)
        sched.on_step_complete(
            out, {s.request.request_id: 1 for s in out.batch}, 1.0)
    assert not seen_mixed, "sglang policy must not mix prefill with decode"


def test_chunked_prefill_spans_steps():
    cfg, bm, pc, sched = make_sched(budget=8)
    r = make_req(prompt_len=30, out=2, rid=4005)
    sched.add_request(r)
    out1 = sched.schedule(0.0)
    assert out1.batch[0].num_new_tokens == 8
    sched.on_step_complete(out1, {}, 0.1)
    assert r.num_prefilled == 8
    out2 = sched.schedule(0.2)
    assert out2.batch[0].num_new_tokens == 8
    # 30 tokens => chunks 8,8,8,6
    sched.on_step_complete(out2, {}, 0.3)
    out3 = sched.schedule(0.4)
    sched.on_step_complete(out3, {}, 0.5)
    out4 = sched.schedule(0.6)
    assert out4.batch[0].num_new_tokens == 6
    sched.on_step_complete(out4, {4005: 7}, 0.7)
    assert r.prefill_complete and r.output_tokens == [7]
    assert r.first_token_time == 0.7


def test_preemption_under_memory_pressure():
    # 8 blocks x 4 = 32 token slots; two requests with long decodes collide
    cfg, bm, pc, sched = make_sched(num_blocks=8, block_size=4, budget=64,
                                    prefix=False)
    ra = make_req(prompt_len=12, out=20, rid=4006)
    rb = make_req(prompt_len=12, out=20, rid=4007)
    sched.add_request(ra)
    sched.add_request(rb)
    preempted = 0
    for i in range(40):
        out = sched.schedule(float(i))
        if out.is_empty:
            break
        preempted += len(out.preempted)
        sched.on_step_complete(
            out, {s.request.request_id: 1 for s in out.batch}, float(i) + 0.5)
        if ra.finished and rb.finished:
            break
    assert preempted >= 1, "memory pressure must trigger preemption"
    assert ra.finished and rb.finished, "both requests must still complete"
    assert ra.num_generated == 20 and rb.num_generated == 20
    # all memory returned
    assert bm.num_free == 8


def test_prefix_cache_skips_recompute_in_scheduler():
    cfg, bm, pc, sched = make_sched(budget=64)
    ra = make_req(prompt_len=16, out=2, rid=4008)
    sched.add_request(ra)
    drive(sched, steps=4)
    assert ra.finished
    rb = make_req(prompt_len=16, out=2, rid=4009)
    rb.prompt_tokens = list(ra.prompt_tokens)
    sched.add_request(rb)
    out = sched.schedule(10.0)
    [s] = out.batch
    # 12 of 16 tokens cache-hit (last block never skipped entirely)
    assert rb.cached_prefix_len == 12
    assert s.num_new_tokens == 4


def test_fcfs_admission_order_and_max_seqs():
    cfg, bm, pc, sched = make_sched(budget=1024, max_seqs=2)
    rs = [make_req(prompt_len=8, out=4, rid=4100 + i) for i in range(4)]
    for r in rs:
        sched.add_request(r)
    out = sched.schedule(0.0)
    admitted = [r.request_id for r in out.admitted]
    assert admitted == [4100, 4101], "FCFS order, capped at max_num_seqs"


@settings(max_examples=25, deadline=None)
@given(policy=st.sampled_from(["vllm", "sglang"]),
       budget=st.sampled_from([4, 8, 16]),
       n_reqs=st.integers(1, 6),
       seed=st.integers(0, 100))
def test_scheduler_property_all_requests_finish(policy, budget, n_reqs, seed):
    """Property: any workload drains — every request finishes with exactly
    max_new_tokens outputs and all KV blocks returned."""
    import numpy as np
    rng = np.random.default_rng(seed)
    cfg, bm, pc, sched = make_sched(policy=policy, num_blocks=256,
                                    block_size=4, budget=budget, max_seqs=4)
    reqs = []
    for i in range(n_reqs):
        r = make_req(prompt_len=int(rng.integers(1, 40)),
                     out=int(rng.integers(1, 10)), rid=6000 + seed * 10 + i)
        reqs.append(r)
        sched.add_request(r)
    for step in range(500):
        if all(r.finished for r in reqs):
            break
        out = sched.schedule(float(step))
        sched.on_step_complete(
            out, {s.request.request_id: 1 for s in out.batch},
            float(step) + 0.5)
    assert all(r.finished for r in reqs)
    for r in reqs:
        assert r.num_generated == r.max_new_tokens
        assert r.request_id not in bm.block_tables
    held = sum(1 for b in bm._blocks if b.ref_count > 0)
    assert held == pc.num_cached_blocks()    # only the cache holds blocks
