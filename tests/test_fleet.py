"""Fleet plane: specs, ingress WRR, adapter affinity, per-tenant metrics,
swap accounting, partitioned counterfactual, and thread-vs-DES parity.

Covers the codec contract for the new nested list-valued fields (SpecError
with *indexed* dotted paths, e.g. ``fleet.tenants[1].slo.ttft_s``) and the
acceptance invariants: per-tenant conservation (completed + failed ==
submitted), fairness bounds, and the multi-LoRA shared-base parity cell.
"""

import dataclasses

import pytest

from repro.fleet import (AdapterSpec, FleetSpec, ModelPoolSpec, ModelRouter,
                         TenantSpec, jain_index, partitioned_fleet)
from repro.scenario import (PoolSpec, RoutingSpec, Scenario, SLOSpec,
                            SpecError, WorkloadSpec, compare, get_preset,
                            run)
from repro.workload import WorkloadConfig, synthesize

pytestmark = pytest.mark.timeout(300)


def tiny_fleet(swap_s: float = 0.0, **workload_kw) -> Scenario:
    """One qwen pool, two adapter tenants + one base tenant; deterministic
    (uniform arrivals, static 100 ms steps)."""
    wl = dict(kind="open", qps=2.0, arrival="uniform", num_requests=8,
              prompt_len_mean=24.0, max_prompt_len=48,
              output_len_mean=4.0, max_output_len=5)
    wl.update(workload_kw)
    return Scenario(
        name="tiny_fleet",
        workload=WorkloadSpec(**wl),
        fleet=FleetSpec(
            models=(ModelPoolSpec(
                name="m",
                pool=PoolSpec(model="qwen2_5_3b", reduced=True, replicas=2,
                              max_num_seqs=8, max_batched_tokens=64,
                              block_size=4, num_blocks=4096,
                              enable_prefix_caching=False,
                              step_time_s=100e-3),
                routing=RoutingSpec(policy="adapter_affinity"),
                adapters=(AdapterSpec(name="a", kv_blocks=32, swap_s=swap_s),
                          AdapterSpec(name="b", kv_blocks=32,
                                      swap_s=swap_s))),),
            tenants=(
                TenantSpec(name="t1", share=2.0, model="m", adapter="a",
                           slo=SLOSpec(ttft_s=2.0)),
                TenantSpec(name="t2", share=1.0, model="m", adapter="b",
                           slo=SLOSpec(ttft_s=2.0)),
                TenantSpec(name="t3", share=1.0, model="m",
                           slo=SLOSpec(ttft_s=2.0)),
            )),
        slo=SLOSpec(ttft_s=2.0),
        seed=17)


# =========================================================================
# specs + codec error paths (satellite: indexed dotted paths)
# =========================================================================

def test_fleet_mix_round_trips():
    s = get_preset("fleet_mix")
    assert Scenario.from_dict(s.to_dict()) == s
    assert Scenario.from_json(s.to_json()) == s


def test_unknown_key_in_nested_list_carries_indexed_path():
    d = get_preset("fleet_mix").to_dict()
    d["fleet"]["tenants"][1]["slo"] = {"ttft_x": 1.0}
    with pytest.raises(SpecError, match=r"fleet\.tenants\[1\]\.slo\.ttft_x"):
        Scenario.from_dict(d)


def test_unknown_key_in_adapters_carries_indexed_path():
    d = get_preset("fleet_mix").to_dict()
    d["fleet"]["models"][0]["adapters"][1]["swap_x"] = 1.0
    with pytest.raises(
            SpecError,
            match=r"fleet\.models\[0\]\.adapters\[1\]\.swap_x"):
        Scenario.from_dict(d)


def test_unknown_key_in_faults_carries_indexed_path():
    with pytest.raises(SpecError, match=r"faults\[0\]\.nope"):
        Scenario.from_dict({"faults": [{"kind": "crash", "nope": 1}]})


def test_validation_errors_carry_indexed_paths():
    base = tiny_fleet()
    # duplicate tenant name
    f = base.fleet
    dup = dataclasses.replace(
        f, tenants=(f.tenants[0],
                    dataclasses.replace(f.tenants[1], name="t1"),
                    f.tenants[2]))
    with pytest.raises(SpecError, match=r"fleet\.tenants\[1\]\.name"):
        dataclasses.replace(base, fleet=dup).validate()
    # dangling model reference
    dangle = dataclasses.replace(
        f, tenants=(dataclasses.replace(f.tenants[0], model="ghost"),)
        + f.tenants[1:])
    with pytest.raises(SpecError, match=r"fleet\.tenants\[0\]\.model"):
        dataclasses.replace(base, fleet=dangle).validate()
    # dangling adapter reference
    bad_adapter = dataclasses.replace(
        f, tenants=(dataclasses.replace(f.tenants[0], adapter="ghost"),)
        + f.tenants[1:])
    with pytest.raises(SpecError, match=r"fleet\.tenants\[0\]\.adapter"):
        dataclasses.replace(base, fleet=bad_adapter).validate()
    # adapter overhead eating the whole pool
    fat = dataclasses.replace(
        f, models=(dataclasses.replace(
            f.models[0],
            adapters=(AdapterSpec(name="a", kv_blocks=5000),)),))
    fat = dataclasses.replace(
        fat, tenants=tuple(dataclasses.replace(t, adapter=None)
                           if t.adapter == "b" else t for t in f.tenants))
    with pytest.raises(SpecError, match=r"fleet\.models\[0\]\.adapters"):
        dataclasses.replace(base, fleet=fat).validate()


def test_fleet_cross_validation():
    base = tiny_fleet()
    with pytest.raises(SpecError, match="fleet"):
        dataclasses.replace(
            base, workload=WorkloadSpec(kind="sessions")).validate()
    with pytest.raises(SpecError, match="autoscale"):
        from repro.scenario import AutoscaleSpec
        dataclasses.replace(
            base, autoscale=AutoscaleSpec(policy="queue_depth"),
            pool=PoolSpec(replicas=2)).validate()
    with pytest.raises(SpecError, match="pd_pool"):
        bad = dataclasses.replace(
            base.fleet, models=(dataclasses.replace(
                base.fleet.models[0],
                routing=RoutingSpec(policy="pd_pool")),))
        dataclasses.replace(base, fleet=bad).validate()


def test_adapter_kv_debit():
    mp = tiny_fleet().fleet.models[0]
    assert mp.pool.num_blocks == 4096
    assert mp.effective_pool().num_blocks == 4096 - 64


# =========================================================================
# ingress (deterministic WRR)
# =========================================================================

def _reqs(n, qps=4.0):
    return synthesize(WorkloadConfig(
        num_requests=n, qps=qps, arrival="uniform", prompt_len_mean=16,
        output_len_mean=4, max_prompt_len=32, max_output_len=8, seed=3))


def test_wrr_assignment_matches_shares():
    fleet = tiny_fleet().fleet
    asn = ModelRouter(fleet).assign(_reqs(16))
    # shares 2:1:1 over 16 requests -> exactly 8/4/4
    assert asn.submitted == {"t1": 8, "t2": 4, "t3": 4}
    # smooth WRR interleaves: the 2-share tenant never waits two slots
    assert asn.ingress[:4] == ["t1", "t2", "t3", "t1"]
    # assignment is a function of the spec alone: re-running is identical
    asn2 = ModelRouter(fleet).assign(_reqs(16))
    assert asn2.ingress == asn.ingress


def test_ingress_tags_requests():
    fleet = tiny_fleet().fleet
    reqs = _reqs(8)
    asn = ModelRouter(fleet).assign(reqs)
    assert set(asn.pools) == {"m"}
    for r in asn.pools["m"]:
        assert r.tenant in {"t1", "t2", "t3"}
        expected = {"t1": "a", "t2": "b", "t3": None}[r.tenant]
        assert r.adapter == expected


def test_swap_shift_applies_once_per_adapter():
    fleet = tiny_fleet(swap_s=0.5).fleet
    reqs = _reqs(8)
    asn = ModelRouter(fleet).assign(reqs)
    # exactly one cold load per adapter (a and b), 0.5 s each
    assert sorted(asn.swap_shift.values()) == [0.5, 0.5]


# =========================================================================
# adapter-affinity routing (unit)
# =========================================================================

class _View:
    def __init__(self, tokens):
        self._t = tokens

    def outstanding_tokens(self):
        return self._t

    def prefix_match_len(self, toks):
        return 0


class _Req:
    def __init__(self, adapter=None):
        self.adapter = adapter


def test_adapter_affinity_sticky_and_rebalance():
    from repro.cluster.router import make_router
    r = make_router("adapter_affinity", 3)
    views = [_View(100), _View(0), _View(50)]
    # first placement: shortest drain -> replica 1; then sticky
    assert r.route(_Req("a"), views) == 1
    assert r.route(_Req("a"), [_View(0), _View(999), _View(0)]) == 1
    # a different adapter places independently
    assert r.route(_Req("b"), [_View(0), _View(999), _View(50)]) == 0
    # base traffic ignores the sticky map
    assert r.route(_Req(None), [_View(9), _View(1), _View(5)]) == 1
    # sticky replica drained away -> deterministic re-place among active
    assert r.route(_Req("a"), views, active=[0, 2]) == 2
    assert r.adapter_placements() == {"a": 2, "b": 0}


# =========================================================================
# end-to-end: metrics, conservation, swap accounting, parity
# =========================================================================

def test_per_tenant_conservation_and_fairness():
    res = run(tiny_fleet(), "thread", timeout=120)
    assert res.tenants is not None and len(res.tenants) == 3
    total = 0
    for row in res.tenants.values():
        assert row["completed"] + row["failed"] == row["submitted"]
        total += row["submitted"]
    assert total == 8 == res.num_requests
    assert 0.0 < res.fairness <= 1.0
    atts = [row["attainment"] for row in res.tenants.values()]
    assert res.fairness == pytest.approx(jain_index(atts))
    assert res.tenant_attainment() == pytest.approx(1.0)


def test_swap_penalty_lands_in_reported_latency():
    cold = run(tiny_fleet(swap_s=0.5), "thread", timeout=120)
    warm = run(tiny_fleet(swap_s=0.0), "thread", timeout=120)
    # exactly the two first-adapter requests pay exactly the cold load
    diffs = [cold.latencies[k][0] - warm.latencies[k][0]
             for k in warm.latencies]
    paying = [d for d in diffs if d > 1e-9]
    assert len(paying) == 2
    assert all(d == pytest.approx(0.5) for d in paying)


def test_fleet_thread_des_parity():
    c = compare(tiny_fleet(swap_s=0.25), ("thread", "des"), timeout=120)
    assert c.decisions_equal and c.completed_equal
    assert c.max_err_steps <= 1.0


def test_fleet_mix_preset_thread_des_parity():
    c = compare(get_preset("fleet_mix"), ("thread", "des"), timeout=300)
    assert c.decisions_equal and c.scaleup_tiers_equal
    assert c.max_err_steps <= 1.0


def test_partitioned_fleet_costs_more():
    mux = tiny_fleet()
    part = partitioned_fleet(mux)
    assert len(part.fleet.models) == 3          # one dedicated pool each
    assert {t.model for t in part.fleet.tenants} == \
        {m.name for m in part.fleet.models}
    r_mux = run(mux, "des", timeout=120)
    r_part = run(part, "des", timeout=120)
    assert r_part.replica_seconds > r_mux.replica_seconds
    # attainment does not improve by partitioning at this utilization
    assert r_mux.tenant_attainment() >= r_part.tenant_attainment() - 1e-9


def test_fleet_requires_full_audit():
    with pytest.raises(SpecError, match="audit"):
        run(tiny_fleet(), "thread", audit="sampled")


def test_jain_index_bounds():
    assert jain_index([]) == 1.0
    assert jain_index([0.0, 0.0]) == 1.0
    assert jain_index([1.0, 1.0, 1.0]) == pytest.approx(1.0)
    assert jain_index([1.0, 0.0, 0.0, 0.0]) == pytest.approx(0.25)
