"""Declarative scenario API: serialization strictness, sweep expansion, the
uniform run() surface, and cross-backend parity through compare().

Serialization is the load-bearing contract (scenario files are the new
config surface): round trips must be exact and *byte-stable*, and invalid
input must fail with the dotted path of the offending entry — a typo'd
sweep file pointing at "autoscale.polcy" should say so.

The compare() tests cover every backend pair on a small mixed-tier
autoscaling scenario (the ``elastic_tier_parity`` preset): one spec, three
execution engines, ≤ 1-slow-step agreement — the repo's parity bar as a
single API call.  Process-backed pairs spawn real child processes and carry
timeout markers.
"""

import json

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # optional dev dependency
    from _hypothesis_compat import given, settings, st

from repro.scenario import (AutoscaleSpec, ParityError, PoolSpec, RoutingSpec,
                            Scenario, SLOSpec, SpecError, Sweep, WorkloadSpec,
                            compare, get_preset, list_presets, run,
                            scenario_with)

MIXED_TIER_AUTOSCALE = "elastic_tier_parity"   # the backend-pair scenario


def full_scenario() -> Scenario:
    """A scenario exercising every spec field family at once."""
    return Scenario(
        name="full",
        workload=WorkloadSpec(
            kind="sessions", qps=3.0, arrival="gamma",
            arrival_kwargs={"cv2": 8.0}, num_sessions=4, turns_mean=2.0,
            max_turns=3, think_time_mean=0.4, prompt_len_mean=30.0,
            followup_len_mean=10.0, output_len_mean=6.0, max_output_len=10),
        pool=PoolSpec(
            model="qwen2_5_3b", reduced=True, replicas=2,
            tiers=("h100", "l4"), max_num_seqs=4, max_batched_tokens=64,
            block_size=4, num_blocks=2048, enable_prefix_caching=False,
            tier_step_time_s={"h100": 5e-3, "l4": 12.5e-3}),
        routing=RoutingSpec(policy="least_outstanding_tokens"),
        autoscale=AutoscaleSpec(
            policy="schedule", schedule=((0.5, 1), (2.0, -1)),
            interval_s=0.1, provision_delay_s=0.2, min_replicas=1,
            max_replicas=3, tiers=("h100", "l4"),
            provision_delay_by_tier={"l4": 0.1}),
        slo=SLOSpec(ttft_s=0.5, tpot_s=0.1),
        seed=7)


# =========================================================================
# serialization: round trips
# =========================================================================

def test_default_scenario_round_trips():
    s = Scenario()
    assert Scenario.from_dict(s.to_dict()) == s
    assert Scenario.from_json(s.to_json()) == s


def test_full_scenario_round_trips():
    s = full_scenario()
    assert Scenario.from_dict(s.to_dict()) == s
    assert Scenario.from_json(s.to_json()) == s


def test_round_trip_is_byte_stable():
    """to_json(from_json(text)) == text: the serialized form is a fixed
    point, so spec files diff cleanly across tooling round trips."""
    for s in (Scenario(), full_scenario(), get_preset("hetero_mix")):
        text = s.to_json()
        assert Scenario.from_json(text).to_json() == text


def test_every_preset_round_trips():
    for name in list_presets():
        s = get_preset(name)
        assert Scenario.from_dict(s.to_dict()) == s, name
        assert Scenario.from_json(s.to_json()).to_json() == s.to_json(), name


def test_tuples_come_back_as_tuples():
    s = full_scenario()
    d = s.to_dict()
    assert isinstance(d["pool"]["tiers"], list)          # JSON form
    back = Scenario.from_dict(d)
    assert isinstance(back.pool.tiers, tuple)            # spec form
    assert isinstance(back.autoscale.schedule[0], tuple)


def test_save_load_file(tmp_path):
    s = full_scenario()
    path = tmp_path / "scenario.json"
    s.save(path)
    assert Scenario.load(path) == s


def test_empty_dict_is_a_valid_scenario():
    assert Scenario.from_dict({}) == Scenario()


# =========================================================================
# serialization: strictness (path-carrying errors)
# =========================================================================

@pytest.mark.parametrize("payload,needle", [
    ({"nope": 1}, "nope"),
    ({"pool": {"replicaz": 2}}, "pool.replicaz"),
    ({"workload": {"kind": "closed"}}, "workload.kind"),
    ({"workload": {"arrival": "psn"}}, "workload.arrival"),
    ({"workload": {"arrival": "uniform", "arrival_kwargs": {"cv2": 8.0}}},
     "workload.arrival_kwargs"),
    ({"workload": {"qps": "fast"}}, "workload.qps"),
    ({"pool": {"model": "gpt-17"}}, "pool.model"),
    ({"pool": {"replicas": 2, "tiers": ["h100", "warpcore"]}},
     "pool.tiers[1]"),
    ({"pool": {"replicas": True}}, "pool.replicas"),
    ({"routing": {"policy": "warp_drive"}}, "routing.policy"),
    ({"autoscale": {"policy": "psychic"}}, "autoscale.policy"),
    ({"autoscale": {"policy": "queue_depth", "schedule": [[0.1, 1]]}},
     "autoscale.schedule"),
    ({"autoscale": {"policy": "schedule"}}, "autoscale.schedule"),
    ({"autoscale": {"policy": "schedule", "schedule": [[0.1]]}},
     "autoscale.schedule[0]"),
    ({"slo": {"ttft_s": -1.0}}, "slo.ttft_s"),
])
def test_invalid_specs_raise_with_offending_path(payload, needle):
    with pytest.raises(SpecError) as exc:
        Scenario.from_dict(payload)
    assert needle in str(exc.value), \
        f"error {exc.value} does not point at {needle}"


def test_tier_count_must_match_replicas():
    with pytest.raises(SpecError) as exc:
        Scenario.from_dict({"pool": {"replicas": 3,
                                     "tiers": ["h100", "l4"]}})
    assert "pool.tiers" in str(exc.value)


def test_pool_outside_autoscale_bounds_rejected():
    with pytest.raises(SpecError) as exc:
        Scenario.from_dict({
            "pool": {"replicas": 8},
            "autoscale": {"policy": "queue_depth", "max_replicas": 4}})
    assert "pool.replicas" in str(exc.value)


def test_run_rejects_unknown_backend():
    with pytest.raises(SpecError):
        run(Scenario(), backend="quantum")


# =========================================================================
# serialization: randomized property (hypothesis or the local compat shim)
# =========================================================================

@settings(max_examples=30, deadline=None)
@given(
    kind=st.sampled_from(["open", "sessions"]),
    qps=st.floats(min_value=0.5, max_value=40.0),
    count=st.integers(min_value=1, max_value=60),
    arrival=st.sampled_from(["uniform", "poisson", "gamma"]),
    policy=st.sampled_from(["round_robin", "least_outstanding_tokens",
                            "cost_normalized_load", "prefix_affinity"]),
    replicas=st.integers(min_value=1, max_value=5),
    tiered=st.booleans(),
    elastic=st.booleans(),
    slo=st.floats(min_value=0.05, max_value=3.0),
    seed=st.integers(min_value=0, max_value=1 << 20),
)
def test_random_specs_round_trip(kind, qps, count, arrival, policy,
                                 replicas, tiered, elastic, slo, seed):
    s = Scenario(
        name=f"prop-{seed}",
        workload=WorkloadSpec(kind=kind, qps=qps, arrival=arrival,
                              num_requests=count, num_sessions=count),
        pool=PoolSpec(replicas=replicas,
                      tiers=("l4",) if tiered else None,
                      step_time_s=None if tiered else 5e-3,
                      tier_step_time_s={"l4": 5e-3} if tiered else None),
        routing=RoutingSpec(policy=policy),
        autoscale=AutoscaleSpec(policy="queue_depth",
                                kwargs={"target_depth": 4.0},
                                min_replicas=1,
                                max_replicas=max(replicas, 6))
        if elastic else None,
        slo=SLOSpec(ttft_s=slo),
        seed=seed)
    s.validate()
    assert Scenario.from_dict(s.to_dict()) == s
    text = s.to_json()
    assert Scenario.from_json(text) == s
    assert Scenario.from_json(text).to_json() == text
    # the dict form is pure JSON (no tuples/sets sneak through)
    json.dumps(s.to_dict())


# =========================================================================
# scenario_with + Sweep
# =========================================================================

def test_scenario_with_replaces_nested_fields():
    s = Scenario()
    s2 = scenario_with(s, **{"pool.replicas": 4, "workload.qps": 9.0,
                             "routing.policy": "prefix_affinity"})
    assert (s2.pool.replicas, s2.workload.qps, s2.routing.policy) == \
        (4, 9.0, "prefix_affinity")
    assert s.pool.replicas == 2            # original untouched (frozen tree)
    with pytest.raises(SpecError):
        scenario_with(s, **{"pool.replicas": "many"})
    with pytest.raises(SpecError):
        scenario_with(s, **{"autoscale.interval_s": 1.0})  # autoscale=None


def test_sweep_expands_in_product_order_with_cell_names():
    sweep = Sweep(Scenario(name="g"), {"pool.replicas": [1, 2],
                                       "workload.qps": [4.0, 8.0]})
    cells = sweep.expand()
    assert len(sweep) == len(cells) == 4
    assert [(c.pool.replicas, c.workload.qps) for c in cells] == \
        [(1, 4.0), (1, 8.0), (2, 4.0), (2, 8.0)]
    assert cells[0].name == "g[replicas=1,qps=4.0]"
    assert Sweep.from_dict(sweep.to_dict()) == sweep


def test_sweep_rejects_bad_axes():
    with pytest.raises(SpecError):
        Sweep(Scenario(), {"pool.replicas": []})
    with pytest.raises(SpecError):
        Sweep(Scenario(), {"pool.nope": [1]}).expand()
    with pytest.raises(SpecError):
        Sweep(Scenario(), {"routing.policy": ["warp_drive"]}).expand()


# =========================================================================
# run(): the uniform surface (cheap backends only; thread/process runs are
# covered by the compare tests and the benchmark smoke job)
# =========================================================================

def test_des_run_returns_uniform_result():
    res = run(get_preset(MIXED_TIER_AUTOSCALE), backend="des")
    assert res.backend == "des"
    assert res.num_requests == 10
    assert res.replica_tiers == ["h100", "l4", "l4"]
    assert res.tiers_added == ["l4"]
    assert res.ttft.p50 > 0 and res.makespan_virtual > 0
    assert res.cost_dollars > 0
    assert set(res.tier_seconds) == {"h100", "l4"}
    row = res.to_row()
    assert row["scenario"] == MIXED_TIER_AUTOSCALE
    assert row["tiers_added"] == "l4"


def test_same_seed_des_runs_are_identical():
    a = run(get_preset(MIXED_TIER_AUTOSCALE), backend="des")
    b = run(get_preset(MIXED_TIER_AUTOSCALE), backend="des")
    assert a.latencies == b.latencies
    assert a.routing_decisions == b.routing_decisions


def test_des_rejects_pd_pool():
    s = scenario_with(Scenario(), **{"routing.policy": "pd_pool",
                                     "pool.replicas": 2})
    with pytest.raises(SpecError):
        run(s, backend="des")


# =========================================================================
# compare(): one backend pair per test on the mixed-tier autoscaling spec
# =========================================================================

def _check_pair(cres):
    assert cres.completed_equal
    assert cres.decisions_equal
    assert cres.scaleup_tiers_equal and cres.drained_equal
    assert cres.max_err_steps <= 1.0
    rs = list(cres.results.values())
    assert all(r.num_requests == rs[0].num_requests for r in rs)
    assert all(r.replica_tiers == ["h100", "l4", "l4"] for r in rs)


def test_compare_thread_vs_des_mixed_tier_autoscale():
    _check_pair(compare(get_preset(MIXED_TIER_AUTOSCALE),
                        backends=("thread", "des"), timeout=120))


@pytest.mark.timeout(300)
def test_compare_thread_vs_process_mixed_tier_autoscale():
    _check_pair(compare(get_preset(MIXED_TIER_AUTOSCALE),
                        backends=("thread", "process"), timeout=120))


@pytest.mark.timeout(300)
def test_compare_process_vs_des_mixed_tier_autoscale():
    _check_pair(compare(get_preset(MIXED_TIER_AUTOSCALE),
                        backends=("process", "des"), timeout=120))


def test_compare_detects_semantic_divergence():
    """The bar must bite: prefix caching is exactly the Table-1 semantic
    gap the DES cannot model.  A session workload whose follow-up turns
    carry long contexts makes the emulator's cached prefill several chunks
    shorter than the DES re-prefill — more than one slow-step — and
    compare must refuse."""
    s = scenario_with(
        get_preset("distributed_parity"),
        name="semantic_gap",
        **{"workload.kind": "sessions", "workload.num_sessions": 3,
           "workload.qps": 1.0,
           "workload.turns_mean": 3.0, "workload.max_turns": 3,
           "workload.think_time_mean": 0.3,
           "workload.prompt_len_mean": 150.0,
           "workload.max_prompt_len": 300,
           "workload.followup_len_mean": 80.0,
           "pool.replicas": 1,
           "pool.enable_prefix_caching": True})
    with pytest.raises(ParityError):
        compare(s, backends=("thread", "des"), timeout=120)


def test_compare_needs_two_backends():
    with pytest.raises(SpecError):
        compare(get_preset(MIXED_TIER_AUTOSCALE), backends=("thread",))
