"""moe_a2a (shard_map EP all-to-all) vs moe (ragged dropless): numerical
agreement on a multi-device mesh.  Runs in a subprocess because the device
count must be set before JAX initialises (the main test process keeps 1).
"""

import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.models.config import ModelConfig, MoEConfig
    from repro.models import layers as L

    cfg = ModelConfig(
        arch_id="moe_test", family="moe", num_layers=1, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=256, head_dim=16,
        # capacity_factor = num_experts: capacity can hold every token even
        # if all route to one shard -> zero drops -> must match ragged
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=128,
                      capacity_factor=8.0),
    )
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    key = jax.random.key(0)
    p = L.moe_params(cfg, key, jnp.float32)
    B, T = 4, 16
    x = jax.random.normal(jax.random.key(1), (B, T, cfg.d_model), jnp.float32)

    with mesh:
        y_ref, aux_ref = jax.jit(lambda p, x: L.moe(cfg, p, x))(p, x)
        y_a2a, aux_a2a = jax.jit(lambda p, x: L.moe_a2a(cfg, p, x))(p, x)

    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_a2a),
                               rtol=2e-4, atol=2e-4)
    # aux loss is computed per shard on local statistics; only check finite
    assert np.isfinite(float(aux_a2a["moe_aux_loss"]))

    # the lowering must actually contain all-to-all collectives
    with mesh:
        txt = jax.jit(lambda p, x: L.moe_a2a(cfg, p, x)).lower(p, x)\\
            .compile().as_text()
    assert "all-to-all" in txt, "a2a MoE must lower to all-to-all"
    print("MOE_A2A_OK")
""")


def test_moe_a2a_matches_ragged():
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        timeout=300, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                          "HOME": "/root",
                          # force CPU: without this, an installed libtpu
                          # probes cloud instance metadata over the network
                          # (30 slow retries) before falling back — a
                          # multi-minute flaky hang in the sanitised env
                          "JAX_PLATFORMS": "cpu"})
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "MOE_A2A_OK" in proc.stdout
