"""Elastic cluster membership + autoscaling tests: policy units, add/drain
mechanics, closed-loop sessions over the cluster, replica-seconds accounting,
and emulator-vs-DES parity under elastic membership.

Determinism methodology matches tests/test_cluster.py: ManualWallSource runs
advance virtual time only through Timekeeper-coordinated jumps, so elastic
timelines are exactly reproducible.
"""

import copy

import pytest

from repro.cluster import (Autoscaler, AutoscalerConfig, Cluster,
                           QueueDepthPolicy, RoundRobinRouter, SchedulePolicy,
                           TTFTSLOPolicy, build_cluster,
                           make_autoscaler_policy, make_router)
from repro.configs import get_reduced_config
from repro.core.clock import ManualWallSource
from repro.core.predictor import StaticPredictor
from repro.des.simulator import DESConfig, DiscreteEventSimulator
from repro.serving.benchmark import BenchmarkRunner
from repro.serving.scheduler import EngineConfig
from repro.workload import (SessionConfig, SessionWorkload, WorkloadConfig,
                            synthesize)

MODEL = get_reduced_config("qwen2_5_3b")
DT = 5e-3                               # StaticPredictor step duration


def engine_cfg(**kw):
    base = dict(policy="vllm", max_num_seqs=8, max_batched_tokens=64,
                block_size=4, num_blocks=4096)
    base.update(kw)
    return EngineConfig(**base)


def workload(n=16, qps=40.0, seed=3, **kw):
    base = dict(num_requests=n, qps=qps, prompt_len_mean=24,
                output_len_mean=8, max_prompt_len=48, max_output_len=12,
                seed=seed)
    base.update(kw)
    return synthesize(WorkloadConfig(**base))


def session_workload(**kw):
    base = dict(num_sessions=6, qps=3.0, turns_mean=3.0, max_turns=4,
                think_time_mean=0.2, prompt_len_mean=30, followup_len_mean=10,
                output_len_mean=6, max_output_len=10, seed=7)
    base.update(kw)
    return SessionWorkload(SessionConfig(**base))


# =========================================================================
# policy units (fake views, no cluster needed)
# =========================================================================

class FakeView:
    def __init__(self, now=0.0, depths=(0,), ttfts=()):
        self._now, self._depths, self._ttfts = now, list(depths), list(ttfts)

    def now(self):
        return self._now

    def active_count(self):
        return len(self._depths)

    def queue_depths(self):
        return list(self._depths)

    def recent_ttfts(self, window_s):
        return list(self._ttfts)


def test_queue_depth_policy_hysteresis():
    p = QueueDepthPolicy(target_depth=4.0, low_watermark=1.0)
    assert p.decide(FakeView(depths=[9, 9])) == 1     # backlog: scale up
    assert p.decide(FakeView(depths=[2, 3])) == 0     # inside the band
    assert p.decide(FakeView(depths=[0, 0])) == -1    # idle: scale down


def test_ttft_slo_policy():
    p = TTFTSLOPolicy(slo_ttft_s=0.1, target_attainment=0.9, idle_depth=0.5)
    # attainment 50% < 90% target: scale up even though queues look calm
    assert p.decide(FakeView(depths=[1], ttfts=[0.05, 0.5])) == 1
    # attainment fine + backlog: hold
    assert p.decide(FakeView(depths=[3], ttfts=[0.05, 0.06])) == 0
    # attainment fine + idle: release capacity
    assert p.decide(FakeView(depths=[0], ttfts=[0.05, 0.06])) == -1
    # no samples yet + idle queues: scale down, never up
    assert p.decide(FakeView(depths=[0])) == -1


def test_schedule_policy_applies_events_once():
    p = SchedulePolicy([(1.0, +1), (2.0, -1), (2.0, +2)])
    assert p.decide(FakeView(now=0.5)) == 0
    assert p.decide(FakeView(now=1.1)) == 1
    assert p.decide(FakeView(now=1.2)) == 0            # already consumed
    assert p.decide(FakeView(now=5.0)) == 1            # -1 +2 batched
    assert p.decide(FakeView(now=9.0)) == 0


def test_make_autoscaler_policy_registry():
    assert isinstance(make_autoscaler_policy("queue_depth"), QueueDepthPolicy)
    assert isinstance(make_autoscaler_policy("ttft_slo"), TTFTSLOPolicy)
    with pytest.raises(ValueError):
        make_autoscaler_policy("nope")


# =========================================================================
# satellite regression: no shared mutable config defaults
# =========================================================================

def test_cluster_config_default_not_shared():
    a = build_cluster(MODEL, engine_cfg(), 1, predictor=StaticPredictor(DT))
    b = build_cluster(MODEL, engine_cfg(), 1, predictor=StaticPredictor(DT))
    try:
        assert a.cfg is not b.cfg
        a.cfg.kv_link_bandwidth = 1.0
        assert b.cfg.kv_link_bandwidth != 1.0
    finally:
        a.shutdown()
        b.shutdown()


def test_des_config_default_not_shared():
    a = DiscreteEventSimulator(StaticPredictor(DT))
    b = DiscreteEventSimulator(StaticPredictor(DT))
    assert a.cfg is not b.cfg


# =========================================================================
# add/drain mechanics
# =========================================================================

def drive(cluster, reqs, *, autoscaler=None, timeout=120.0):
    return BenchmarkRunner(cluster, reqs, transport=cluster.transport,
                           autoscaler=autoscaler).run(timeout=timeout)


def test_add_replica_joins_routing():
    cluster = build_cluster(MODEL, engine_cfg(), 1, policy="round_robin",
                            predictor=StaticPredictor(DT),
                            wall=ManualWallSource())
    try:
        cluster.start()
        assert cluster.num_active() == 1
        idx = cluster.add_replica()
        assert idx == 1 and cluster.num_active() == 2
        assert cluster.router.num_replicas == 2
        reqs = workload(n=8, qps=1e6)
        for r in reqs:
            cluster.submit(r)
        assert cluster.wait_until_complete(8, timeout=60)
        # round robin over the grown membership: both replicas served
        assert set(cluster.router.decisions) == {0, 1}
        assert cluster.engines[1].stats()["finished"] > 0
    finally:
        cluster.shutdown()


def test_drain_replica_stops_routing_and_finishes_in_flight():
    cluster = build_cluster(MODEL, engine_cfg(), 2, policy="round_robin",
                            predictor=StaticPredictor(DT),
                            wall=ManualWallSource())
    try:
        cluster.start()
        reqs = workload(n=10, qps=1e6)
        for r in reqs[:6]:
            cluster.submit(r)
        cluster.drain_replica(1)         # mid-flight: replica 1 has work
        assert cluster.num_active() == 1
        for r in reqs[6:]:
            cluster.submit(r)
        assert cluster.wait_until_complete(10, timeout=60)
        # every request routed after the drain landed on replica 0
        assert all(d == 0 for d in cluster.router.decisions[6:])
        # all in-flight work on the drained replica still completed
        assert len(cluster.finished) == 10
        m = cluster.membership_events()[1]
        assert m["drain_started"] is not None
        assert m["drained"] is not None
        assert m["drained"] >= m["drain_started"]
        with pytest.raises(ValueError):
            cluster.drain_replica(1)     # already drained
    finally:
        cluster.shutdown()


def test_drain_last_replica_refused():
    cluster = build_cluster(MODEL, engine_cfg(), 1,
                            predictor=StaticPredictor(DT))
    try:
        with pytest.raises(AssertionError):
            cluster.drain_replica(0)
    finally:
        cluster.shutdown()


def test_replica_seconds_accounting():
    cluster = build_cluster(MODEL, engine_cfg(), 2,
                            predictor=StaticPredictor(DT),
                            wall=ManualWallSource())
    try:
        # static membership: N * window exactly
        assert cluster.replica_seconds(0.0, 3.0) == pytest.approx(6.0)
        cluster._membership[1]["added"] = 1.0       # joined mid-window
        cluster._membership[1]["drained"] = 2.5     # drained before the end
        assert cluster.replica_seconds(0.0, 3.0) == pytest.approx(3.0 + 1.5)
    finally:
        cluster.shutdown()


# =========================================================================
# closed-loop sessions over the cluster
# =========================================================================

def test_sessions_closed_loop_completes_all_turns():
    sw = session_workload()
    cluster = build_cluster(MODEL, engine_cfg(), 2, policy="round_robin",
                            predictor=StaticPredictor(DT),
                            wall=ManualWallSource())
    try:
        res = drive(cluster, sw)
    finally:
        cluster.shutdown()
    assert res.num_requests == sw.total_requests
    assert res.num_sessions == sw.num_sessions
    assert res.session_ttft is not None and res.session_ttft.p50 > 0
    # closed loop: every turn>0 arrived strictly after its predecessor's
    # finish plus the sampled think time
    by_session = {}
    for r in cluster.finished:
        by_session.setdefault(r.session_id, {})[r.turn_index] = r
    checked = 0
    for sid, turns in by_session.items():
        for k, r in turns.items():
            if k == 0:
                continue
            prev = turns[k - 1]
            think = sw.sessions[sw._index_of(sid)].turns[k].think_time
            assert r.arrival_time >= prev.finish_time + think - 1e-6
            checked += 1
    assert checked > 0, "workload produced no multi-turn sessions"


def test_sessions_exercise_prefix_cache_via_affinity():
    """Follow-up turns carry the prior turn's tokens: with prefix_affinity
    routing they must co-locate with their session's KV and produce real
    radix hits (the point of session-aware synthesis)."""
    sw = session_workload(num_sessions=4, turns_mean=4.0, seed=11)
    cluster = build_cluster(MODEL, engine_cfg(), 2, policy="prefix_affinity",
                            predictor=StaticPredictor(DT),
                            wall=ManualWallSource())
    try:
        drive(cluster, sw)
        hits = sum(e.prefix_cache.stats.hit_tokens for e in cluster.engines)
        assert hits > 0, "session follow-ups produced no radix-cache hits"
        # per-session turn placements are consistent
        sess_replica = {}
        for r in cluster.finished:
            eng = next(i for i, e in enumerate(cluster.engines)
                       if r in e.finished)
            sess_replica.setdefault(r.session_id, set()).add(eng)
        multi = [s for s in sess_replica.values()]
        assert all(len(s) == 1 for s in multi), \
            f"session turns scattered across replicas: {sess_replica}"
    finally:
        cluster.shutdown()


def test_closed_loop_deterministic_timelines():
    def timeline():
        sw = session_workload(seed=23)
        cluster = build_cluster(MODEL, engine_cfg(), 2, policy="round_robin",
                                predictor=StaticPredictor(DT),
                                wall=ManualWallSource())
        try:
            drive(cluster, sw)
            return sorted((r.session_id, r.turn_index, r.arrival_time,
                           r.first_token_time, r.finish_time)
                          for r in cluster.finished)
        finally:
            cluster.shutdown()

    t1, t2 = timeline(), timeline()
    assert len(t1) == len(t2)
    for a, b in zip(t1, t2):
        assert a[:2] == b[:2]
        for x, y in zip(a[2:], b[2:]):
            assert x == pytest.approx(y, abs=1e-9)


# =========================================================================
# autoscaler end-to-end on the emulated cluster
# =========================================================================

def test_autoscaler_scales_up_under_backlog_and_respects_max():
    # sustained overload: one max_num_seqs=4 replica completes ~4 req per
    # 10 steps; 60 qps piles a backlog that only added replicas can absorb,
    # and the stream is long enough that post-provision arrivals exist
    reqs = workload(n=40, qps=60.0, output_len_mean=10)
    cluster = build_cluster(MODEL, engine_cfg(max_num_seqs=4), 1,
                            policy="least_outstanding_tokens",
                            predictor=StaticPredictor(DT),
                            wall=ManualWallSource())
    asc = Autoscaler(cluster, QueueDepthPolicy(target_depth=2.0),
                     AutoscalerConfig(interval_s=0.02,
                                      provision_delay_s=0.05,
                                      min_replicas=1, max_replicas=3))
    try:
        drive(cluster, reqs, autoscaler=asc)
    finally:
        cluster.shutdown()
    ups = sum(d for _, d, _ in asc.decision_log if d > 0)
    assert ups >= 1, "backlog never triggered a scale-up"
    # the engines list is append-only (drained replicas stay parked); the
    # max_replicas cap bounds *active* membership at every decision point
    assert all(active <= 3 for _, _, active in asc.decision_log), \
        "max_replicas breached"
    assert cluster.num_active() <= 3
    assert len(cluster.finished) == 40
    # added replicas actually served work
    assert any(cluster.engines[i].stats()["finished"] > 0
               for i in range(1, len(cluster.engines)))


def test_autoscaler_drains_when_idle():
    # a long quiet tail after a burst: the policy must give capacity back
    reqs = workload(n=12, qps=1e4)
    tail = workload(n=1, qps=1.0, seed=9)
    tail[0].arrival_time = 3.0
    cluster = build_cluster(MODEL, engine_cfg(), 2, policy="round_robin",
                            predictor=StaticPredictor(DT),
                            wall=ManualWallSource())
    asc = Autoscaler(cluster, QueueDepthPolicy(target_depth=4.0,
                                               low_watermark=1.0),
                     AutoscalerConfig(interval_s=0.05, provision_delay_s=0.1,
                                      min_replicas=1, max_replicas=2))
    try:
        drive(cluster, reqs + tail, autoscaler=asc)
    finally:
        cluster.shutdown()
    downs = sum(-d for _, d, _ in asc.decision_log if d < 0)
    assert downs >= 1, "idle cluster never scaled down"
    assert cluster.membership_events()[1]["drained"] is not None
    assert len(cluster.finished) == 13


# =========================================================================
# emulator-vs-DES parity under elastic membership
# =========================================================================

ELASTIC_EVENTS = [(0.08, +1), (0.5, -1)]     # scale up early, drain mid-run
ASC_CFG = AutoscalerConfig(interval_s=0.05, provision_delay_s=0.1,
                           min_replicas=1, max_replicas=2)


def test_elastic_emulator_matches_elastic_des():
    """Scale-up + drain mid-run, same SchedulePolicy on both sides: the
    emulator and the DES must agree on completed counts and per-request
    latencies within one predictor step — the §2.3 parity argument extended
    to elastic membership."""
    reqs = workload(n=16, qps=30.0)
    # tail arrival keeps the run alive past the drain event, so the -1 tick
    # fires deterministically *during* the measured window on both sides
    # (otherwise it lands in the post-completion teardown race)
    reqs[-1].arrival_time = 1.2
    reqs_des = copy.deepcopy(reqs)

    cluster = build_cluster(
        MODEL, engine_cfg(enable_prefix_caching=False), 1,
        policy="round_robin", predictor=StaticPredictor(DT),
        wall=ManualWallSource())
    asc = Autoscaler(cluster, SchedulePolicy(ELASTIC_EVENTS), ASC_CFG)
    try:
        drive(cluster, reqs, autoscaler=asc)
        emu_latency = {r.request_id: r.e2e_latency()
                       for r in cluster.finished}
        assert len(cluster.engines) == 2, "scale-up never happened"
        assert any(d == 1 for _, d, _ in asc.decision_log)
        assert any(d == -1 for _, d, _ in asc.decision_log)
    finally:
        cluster.shutdown()

    des = DiscreteEventSimulator(
        StaticPredictor(DT),
        DESConfig(max_num_seqs=8, max_batched_tokens=64, step_overhead_s=0.0),
        num_replicas=1, router=make_router("round_robin", 1),
        autoscaler_policy=SchedulePolicy(ELASTIC_EVENTS),
        autoscaler_cfg=ASC_CFG)
    sims = des.run(reqs_des)

    assert len(des.replicas) == 2, "DES scale-up never happened"
    assert des.replicas[1].drained_at is not None, "DES drain never finished"
    assert len(emu_latency) == len(reqs)
    assert sum(1 for s in sims if s.finish_time is not None) == len(reqs)
    for orig, sim in zip(reqs_des, sims):
        err = abs(emu_latency[orig.request_id]
                  - (sim.finish_time - sim.arrival_time))
        assert err <= DT + 1e-9, \
            (f"request {orig.request_id}: elastic emulator/DES diverges by "
             f"{err / DT:.2f} steps")


def test_session_emulator_matches_session_des():
    """Closed-loop parity: the same SessionWorkload object drives both the
    emulator (completion-callback re-injection) and the DES (event-loop
    re-injection); per-turn latencies agree within one step."""
    sw = session_workload(num_sessions=5, think_time_mean=0.15, seed=29)

    cluster = build_cluster(
        MODEL, engine_cfg(enable_prefix_caching=False), 2,
        policy="round_robin", predictor=StaticPredictor(DT),
        wall=ManualWallSource())
    try:
        drive(cluster, sw)
        emu = {(r.session_id, r.turn_index): r.e2e_latency()
               for r in cluster.finished}
    finally:
        cluster.shutdown()

    des = DiscreteEventSimulator(
        StaticPredictor(DT),
        DESConfig(max_num_seqs=8, max_batched_tokens=64, step_overhead_s=0.0),
        num_replicas=2, router=make_router("round_robin", 2))
    sims = des.run(sw)

    assert len(sims) == sw.total_requests == len(emu)
    for s in sims:
        assert s.finish_time is not None
        err = abs(emu[(s.session_id, s.turn_index)]
                  - (s.finish_time - s.arrival_time))
        assert err <= DT + 1e-9, \
            (f"session {s.session_id} turn {s.turn_index}: "
             f"emulator/DES diverges by {err / DT:.2f} steps")


def test_des_rejects_pd_pool_still():
    with pytest.raises(ValueError):
        DiscreteEventSimulator(
            StaticPredictor(DT), DESConfig(),
            num_replicas=2, router=make_router("pd_pool", 2))


def test_cluster_rejects_elastic_pd_pool():
    cluster = build_cluster(MODEL, engine_cfg(), 2, policy="pd_pool",
                            predictor=StaticPredictor(DT))
    try:
        with pytest.raises(AssertionError):
            cluster.add_replica()
        with pytest.raises(AssertionError):
            cluster.drain_replica(1)
    finally:
        cluster.shutdown()
