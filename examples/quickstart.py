"""Quickstart: emulate a Llama-3.1-8B vLLM-style deployment without GPUs.

Runs the real serving control plane (continuous batching, chunked prefill,
radix prefix cache) against Revati's time-warp emulation: GPU steps become
virtual-time jumps sized by the analytical runtime predictor, coordinated
causally by the Timekeeper.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.configs import get_config
from repro.serving.benchmark import BenchmarkRunner
from repro.serving.scheduler import EngineConfig
from repro.serving.stack import build_stack
from repro.workload import WorkloadConfig, synthesize


def main() -> None:
    model_cfg = get_config("llama3_8b")          # any of the 13 registry ids
    engine_cfg = EngineConfig(
        policy="vllm",                           # or "sglang"
        max_num_seqs=64,
        max_batched_tokens=512,                  # chunked-prefill budget
        block_size=16,
        num_blocks=32768,
        chip="h200-sxm",                         # emulated hardware target
        tp=1,
    )

    # The whole Revati integration is one argument: mode="emulate".
    stack = build_stack(model_cfg, engine_cfg, mode="emulate")

    requests = synthesize(WorkloadConfig(
        num_requests=100, qps=2.0,               # Poisson arrivals
        prompt_len_mean=220, output_len_mean=180,  # ShareGPT-like
        seed=0,
    ))

    result = BenchmarkRunner(stack.engine, requests,
                             transport=stack.transport).run(timeout=300)
    stack.shutdown()

    print("== emulated deployment report ==")
    for k, v in result.summary().items():
        print(f"  {k:24s} {v:,.3f}" if isinstance(v, float) else
              f"  {k:24s} {v}")
    print(f"\nSimulated {result.makespan_virtual:.1f}s of cluster time in "
          f"{result.wall_seconds:.1f}s of wall time "
          f"({result.speedup:.0f}x acceleration), zero GPUs used.")


if __name__ == "__main__":
    main()
