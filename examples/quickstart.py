"""Quickstart: emulate a Llama-3.1-8B vLLM-style deployment without GPUs.

Runs the real serving control plane (continuous batching, chunked prefill,
radix prefix cache) against Revati's time-warp emulation: GPU steps become
virtual-time jumps sized by the analytical runtime predictor, coordinated
causally by the Timekeeper.

The whole experiment is one declarative :class:`~repro.scenario.Scenario` —
a serializable spec (``scenario.to_json()`` round-trips) that the single
:func:`repro.scenario.run` entry point executes on any backend: the
in-process emulator (``"thread"``, below), replicas as OS processes
(``"process"``), or the discrete-event baseline (``"des"``).

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.scenario import PoolSpec, Scenario, WorkloadSpec, run


def main() -> None:
    scenario = Scenario(
        name="quickstart",
        workload=WorkloadSpec(
            kind="open",
            num_requests=100, qps=2.0,               # Poisson arrivals
            prompt_len_mean=220, output_len_mean=180,  # ShareGPT-like
            max_output_len=1024,
        ),
        pool=PoolSpec(
            model="llama3_8b",                       # any of the 13 registry ids
            replicas=1,
            max_num_seqs=64,
            max_batched_tokens=512,                  # chunked-prefill budget
            block_size=16,
            num_blocks=32768,
            chip="h200-sxm",                         # emulated hardware target
        ),
        seed=0,
    )

    # The whole Revati integration is one argument: backend="thread" (the
    # emulator) vs "des" (the event-driven baseline) vs "process".
    result = run(scenario, backend="thread", timeout=300)

    print("== emulated deployment report ==")
    for k, v in result.to_row().items():
        print(f"  {k:24s} {v:,.3f}" if isinstance(v, float) else
              f"  {k:24s} {v}")
    print(f"\nSimulated {result.makespan_virtual:.1f}s of cluster time in "
          f"{result.wall_seconds:.1f}s of wall time "
          f"({result.speedup:.0f}x acceleration), zero GPUs used.")
    print("\nThe same spec as portable JSON (run it with "
          "`python -m repro.scenario run <file>`):")
    print(scenario.to_json()[:200] + " ...")


if __name__ == "__main__":
    main()
