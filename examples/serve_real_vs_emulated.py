"""End-to-end serving driver: the SAME engine serving a real model on CPU,
then emulated — the paper's core fidelity demonstration.

Phase 1 (real): a reduced Qwen2.5-family model actually executes in JAX —
prompts in, argmax tokens out, batched continuous serving.  Step timings are
profiled into an operator-linear predictor (Vidur-style fit).
Phase 2 (emulate): the identical control plane re-serves the same request
stream with GPU work replaced by predicted time jumps.

    PYTHONPATH=src python examples/serve_real_vs_emulated.py
"""

import jax
import jax.numpy as jnp

from repro.configs import get_reduced_config
from repro.core.predictor import LinearPredictor
from repro.models.transformer import build_model
from repro.serving.benchmark import BenchmarkRunner, compare_distributions
from repro.serving.scheduler import EngineConfig
from repro.serving.stack import build_stack
from repro.workload import WorkloadConfig, synthesize


def workload(seed):
    return synthesize(WorkloadConfig(
        num_requests=24, qps=15.0, prompt_len_mean=24, output_len_mean=8,
        max_prompt_len=96, max_output_len=16, vocab_size=500, seed=seed))


def main() -> None:
    model_cfg = get_reduced_config("qwen2_5_3b")
    engine_cfg = EngineConfig(policy="vllm", max_num_seqs=8,
                              max_batched_tokens=64, block_size=4,
                              num_blocks=4096)
    model = build_model(model_cfg)
    params = model.init(jax.random.key(0), jnp.float32)

    # ---- phase 1: real execution (the model genuinely runs) --------------
    print("phase 1: serving the real model on CPU ...")
    stack = build_stack(model_cfg, engine_cfg, "real", model=model,
                        params=params, max_len=256, max_seqs=8)
    res_real = BenchmarkRunner(stack.engine, workload(7)).run(timeout=900)
    samples = list(stack.runner.samples)
    stack.shutdown()
    print(f"  served {res_real.num_requests} requests in "
          f"{res_real.wall_seconds:.1f}s wall; profiled {len(samples)} steps")

    predictor = LinearPredictor()
    predictor.fit(samples)

    # ---- phase 2: emulated execution (same engine, no model) ------------
    print("phase 2: re-serving the same stream under time-warp emulation ...")
    stack = build_stack(model_cfg, engine_cfg, "emulate",
                        predictor=predictor, use_worker_group=False)
    res_emu = BenchmarkRunner(stack.engine, workload(7),
                              transport=stack.transport).run(timeout=300)
    stack.shutdown()
    print(f"  served {res_emu.num_requests} requests in "
          f"{res_emu.wall_seconds:.2f}s wall "
          f"({res_real.wall_seconds / max(res_emu.wall_seconds, 1e-9):.0f}x "
          f"faster than real)")

    # ---- fidelity report -------------------------------------------------
    ttft = compare_distributions(res_real.ttft, res_emu.ttft)
    tpot = compare_distributions(res_real.tpot, res_emu.tpot)
    print("\nfidelity (emulated vs real):")
    print(f"  TTFT p50  real {res_real.ttft.p50 * 1e3:7.1f} ms   "
          f"emulated {res_emu.ttft.p50 * 1e3:7.1f} ms   "
          f"err {ttft['median_rel_err']:.1%}")
    print(f"  TPOT p50  real {res_real.tpot.p50 * 1e3:7.1f} ms   "
          f"emulated {res_emu.tpot.p50 * 1e3:7.1f} ms   "
          f"err {tpot['median_rel_err']:.1%}")


if __name__ == "__main__":
    main()
