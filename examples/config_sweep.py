"""Configuration search — the workload Revati exists for (paper §2.1).

Sweeps a deployment grid (scheduler policy × chunked-prefill budget × TP
degree) for Qwen3-30B-A3B entirely under emulation, then picks the
max-throughput configuration meeting a p99 TTFT SLO.  On a GPU cluster this
sweep costs hours and thousands of dollars; here it is seconds, GPU-free.

    PYTHONPATH=src python examples/config_sweep.py
"""

import time

from repro.configs import get_config
from repro.serving.benchmark import BenchmarkRunner
from repro.serving.scheduler import EngineConfig
from repro.serving.stack import build_stack
from repro.workload import WorkloadConfig, synthesize

SLO_TTFT_P99_S = 2.0
GRID = [
    dict(policy=p, max_batched_tokens=c, tp=t)
    for p in ("vllm", "sglang")
    for c in (256, 512, 2048)
    for t in (1, 2, 4)
]


def evaluate(cfg_kw: dict) -> dict:
    model_cfg = get_config("qwen3_30b_a3b")
    ecfg = EngineConfig(max_num_seqs=64, block_size=16, num_blocks=32768,
                        chip="h200-sxm", ep=2, **cfg_kw)
    stack = build_stack(model_cfg, ecfg, "emulate", use_worker_group=False)
    try:
        reqs = synthesize(WorkloadConfig(
            num_requests=80, qps=3.0, prompt_len_mean=220,
            output_len_mean=180, seed=1))
        res = BenchmarkRunner(stack.engine, reqs,
                              transport=stack.transport).run(timeout=600)
    finally:
        stack.shutdown()
    return {
        **cfg_kw,
        "ttft_p99_s": round(res.ttft.p99, 3),
        "tpot_p50_ms": round(res.tpot.p50 * 1e3, 2),
        "tokens_per_s": round(res.throughput_tokens_per_s, 1),
        "virtual_s": round(res.makespan_virtual, 1),
        "wall_s": round(res.wall_seconds, 2),
    }


def main() -> None:
    t0 = time.time()
    results = []
    for i, cfg_kw in enumerate(GRID):
        r = evaluate(cfg_kw)
        ok = "ok " if r["ttft_p99_s"] <= SLO_TTFT_P99_S else "SLO✗"
        print(f"[{i + 1:2d}/{len(GRID)}] {ok} {r}")
        results.append(r)

    feasible = [r for r in results if r["ttft_p99_s"] <= SLO_TTFT_P99_S]
    best = max(feasible or results, key=lambda r: r["tokens_per_s"])
    virtual = sum(r["virtual_s"] for r in results)
    wall = time.time() - t0
    print(f"\nbest config under TTFT p99 <= {SLO_TTFT_P99_S}s: "
          f"policy={best['policy']} chunk={best['max_batched_tokens']} "
          f"tp={best['tp']} -> {best['tokens_per_s']} tok/s")
    print(f"explored {len(GRID)} configs = {virtual / 3600:.2f} emulated "
          f"cluster-hours in {wall:.0f}s wall ({virtual / wall:.0f}x)")


if __name__ == "__main__":
    main()
