"""Configuration search — the workload Revati exists for (paper §2.1).

Sweeps a deployment grid (scheduler policy × chunked-prefill budget × TP
degree) for Qwen3-30B-A3B entirely under emulation, then picks the
max-throughput configuration meeting a p99 TTFT SLO.  On a GPU cluster this
sweep costs hours and thousands of dollars; here it is seconds, GPU-free.

With the scenario API the grid is *data*: one base
:class:`~repro.scenario.Scenario` plus a :class:`~repro.scenario.Sweep`
over three axes, and one :func:`repro.scenario.run` call per cell — no
hand-wired stack construction at all.

    PYTHONPATH=src python examples/config_sweep.py
"""

import time

from repro.scenario import PoolSpec, Scenario, Sweep, WorkloadSpec, run

SLO_TTFT_P99_S = 2.0

SWEEP = Sweep(
    Scenario(
        name="config_sweep",
        workload=WorkloadSpec(
            kind="open", num_requests=80, qps=3.0,
            prompt_len_mean=220, output_len_mean=180, max_output_len=1024),
        pool=PoolSpec(
            model="qwen3_30b_a3b", replicas=1, max_num_seqs=64,
            block_size=16, num_blocks=32768, chip="h200-sxm", ep=2),
        seed=1,
    ),
    axes={
        "pool.scheduler": ["vllm", "sglang"],
        "pool.max_batched_tokens": [256, 512, 2048],
        "pool.tp": [1, 2, 4],
    },
)


def evaluate(scenario) -> dict:
    res = run(scenario, backend="thread", timeout=600)
    return {
        "policy": scenario.pool.scheduler,
        "max_batched_tokens": scenario.pool.max_batched_tokens,
        "tp": scenario.pool.tp,
        "ttft_p99_s": round(res.ttft.p99, 3),
        "tpot_p50_ms": round(res.tpot.p50 * 1e3, 2),
        "tokens_per_s": round(res.throughput_tokens_per_s, 1),
        "virtual_s": round(res.makespan_virtual, 1),
        "wall_s": round(res.wall_seconds, 2),
    }


def main() -> None:
    t0 = time.time()
    cells = SWEEP.expand()
    results = []
    for i, scenario in enumerate(cells):
        r = evaluate(scenario)
        ok = "ok " if r["ttft_p99_s"] <= SLO_TTFT_P99_S else "SLO✗"
        print(f"[{i + 1:2d}/{len(cells)}] {ok} {r}")
        results.append(r)

    feasible = [r for r in results if r["ttft_p99_s"] <= SLO_TTFT_P99_S]
    best = max(feasible or results, key=lambda r: r["tokens_per_s"])
    virtual = sum(r["virtual_s"] for r in results)
    wall = time.time() - t0
    print(f"\nbest config under TTFT p99 <= {SLO_TTFT_P99_S}s: "
          f"policy={best['policy']} chunk={best['max_batched_tokens']} "
          f"tp={best['tp']} -> {best['tokens_per_s']} tok/s")
    print(f"explored {len(cells)} configs = {virtual / 3600:.2f} emulated "
          f"cluster-hours in {wall:.0f}s wall ({virtual / wall:.0f}x)")


if __name__ == "__main__":
    main()
