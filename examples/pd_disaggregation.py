"""Prefill/decode disaggregation under emulation (paper Table 1, §2.1).

Two unmodified engines — a prefill stage and a decode stage — share one
Timekeeper; completed prefills migrate their KV cache over an emulated
link whose transfer occupies virtual time.  Compares co-located vs
disaggregated TTFT/TPOT, the deployment question from Mitra et al. the
paper cites (prefill-heavy RAG loads favour disaggregation).

    PYTHONPATH=src python examples/pd_disaggregation.py
"""

from repro.configs import get_config
from repro.core.client import LocalTransport, TimeJumpClient
from repro.core.timekeeper import Timekeeper
from repro.serving.benchmark import BenchmarkRunner, LatencyStats
from repro.serving.disagg import DisaggConfig, DisaggregatedCluster
from repro.serving.engine import LLMEngine
from repro.serving.model_runner import TimeWarpModelRunner
from repro.serving.scheduler import EngineConfig
from repro.serving.stack import build_stack, default_predictor
from repro.workload import WorkloadConfig, synthesize

MODEL = get_config("llama3_8b")


def rag_workload(seed=0):
    """Prefill-heavy (RAG-like): long prompts, short answers."""
    return synthesize(WorkloadConfig(
        num_requests=60, qps=2.0, prompt_len_mean=1600, output_len_mean=60,
        max_prompt_len=4096, seed=seed))


def engine_cfg(**kw):
    base = dict(policy="vllm", max_num_seqs=64, max_batched_tokens=512,
                block_size=16, num_blocks=32768, chip="h200-sxm")
    base.update(kw)
    return EngineConfig(**base)


def run_colocated():
    stack = build_stack(MODEL, engine_cfg(), "emulate",
                        use_worker_group=False)
    try:
        return BenchmarkRunner(stack.engine, rag_workload(),
                               transport=stack.transport).run(timeout=600)
    finally:
        stack.shutdown()


def run_disaggregated():
    tk = Timekeeper(jitter_cooldown=0.0)
    tr = LocalTransport(tk)

    def make_engine(name):
        pred = default_predictor(MODEL, engine_cfg())
        runner = TimeWarpModelRunner(
            pred, TimeJumpClient(tr, f"{name}-w", auto_register=False))
        return LLMEngine(engine_cfg(), runner, tk.clock, name=name)

    cluster = DisaggregatedCluster(
        MODEL, make_engine("prefill"), make_engine("decode"),
        DisaggConfig(kv_link_bandwidth=50e9), transport=tr)
    cluster.start()
    reqs = rag_workload()
    # dispatcher-as-Actor: jump virtual time to each Poisson arrival
    dispatcher = TimeJumpClient(tr, "dispatcher")
    t0 = tk.clock.now()
    try:
        for r in sorted(reqs, key=lambda r: r.arrival_time):
            dispatcher.jump_to(t0 + r.arrival_time)
            r.arrival_time = tk.clock.now()
            cluster.submit(r)
    finally:
        dispatcher.deregister()
    ok = cluster.wait_until_complete(len(reqs), timeout=600)
    assert ok, "disaggregated cluster did not drain"
    fin = cluster.finished
    ttft = LatencyStats.of([r.ttft() for r in fin if r.ttft() is not None])
    tpot = LatencyStats.of([r.tpot() for r in fin
                            if r.tpot() is not None and r.num_generated > 1])
    xfer = LatencyStats.of([r.kv_transfer_time for r in fin])
    cluster.stop()
    tk.close()
    return ttft, tpot, xfer


def main() -> None:
    print("co-located (prefill + decode on one engine) ...")
    co = run_colocated()
    print("disaggregated (separate prefill/decode engines, KV over link) ...")
    ttft, tpot, xfer = run_disaggregated()

    print("\n                 co-located    disaggregated")
    print(f"TTFT p50 (s)     {co.ttft.p50:10.3f}    {ttft.p50:10.3f}")
    print(f"TTFT p99 (s)     {co.ttft.p99:10.3f}    {ttft.p99:10.3f}")
    print(f"TPOT p50 (ms)    {co.tpot.p50 * 1e3:10.2f}    {tpot.p50 * 1e3:10.2f}")
    print(f"TPOT p99 (ms)    {co.tpot.p99 * 1e3:10.2f}    {tpot.p99 * 1e3:10.2f}")
    print(f"\nKV transfer p50 {xfer.p50 * 1e3:.2f} ms over the 50 GB/s link "
          f"(occupies virtual time, preserving causality)")
    print("decode TPOT tail improves when prefill chunks no longer share "
          "the decode engine's steps — the Mitra et al. trade-off, "
          "reproduced for free by running the real control planes.")


if __name__ == "__main__":
    main()
