"""Repo-root pytest bootstrap: never write bytecode during test runs.

Stale ``__pycache__`` dirs under ``src/`` shadow source edits (an old
``.pyc`` with a matching mtime wins over the file you just changed) and
keep sneaking back in.  Tier-1 enforces their absence
(``tests/test_hygiene.py``); this conftest makes the enforcement
self-consistent by ensuring the test run itself — including spawned
replica children, which inherit the environment variable — never creates
what the hygiene test would then flag.
"""

import os
import sys

sys.dont_write_bytecode = True
os.environ["PYTHONDONTWRITEBYTECODE"] = "1"
